//! The evaluation server: function registry + batcher + worker pool.
//!
//! Architecture (std threads + channels; Python never on this path):
//!
//! ```text
//! clients → submit() → [mpsc] → batcher thread → [mpsc] → N workers
//!                                                     ↘ metrics
//! ```
//!
//! Workers execute a whole batch on one engine: the bit-level simulator,
//! the analytic evaluator, or — when `artifacts/smurf_eval.hlo.txt`
//! exists — the AOT-compiled XLA kernel for supported configurations.

use super::batcher::{run_batcher, Batch, BatchPolicy};
use super::metrics::Metrics;
use super::request::{Engine, EvalRequest, EvalResponse};
use crate::runtime::Runtime;
use crate::smurf::approximator::SmurfApproximator;
use std::collections::HashMap;
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Mutex};
use std::time::Instant;

/// Server configuration.
#[derive(Clone, Debug)]
pub struct ServerConfig {
    pub workers: usize,
    pub policy: BatchPolicy,
    /// Artifact name of the XLA smurf_eval kernel (batch-N, M=2, N=4).
    pub xla_artifact: String,
}

impl Default for ServerConfig {
    fn default() -> Self {
        Self {
            workers: std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4).min(8),
            policy: BatchPolicy::default(),
            xla_artifact: "smurf_eval.hlo.txt".into(),
        }
    }
}

/// A job for the dedicated XLA thread (the PJRT client is not `Send` in
/// the `xla` crate, so a single owner thread serializes device access —
/// the same single-queue model a real accelerator backend uses).
struct XlaJob {
    /// Row-major (batch, 2) f32 inputs, padded to the kernel batch.
    xs: Vec<f32>,
    /// 4×4 coefficient table.
    w: Vec<f32>,
    reply: Sender<Result<Vec<f32>, String>>,
}

/// Shared state between workers.
struct Shared {
    functions: HashMap<String, Arc<SmurfApproximator>>,
    metrics: Metrics,
    xla_tx: Option<Sender<XlaJob>>,
}

/// Owner loop for the PJRT runtime: creates the client *inside* the
/// thread (the `xla` crate's handles are not `Send`), compiles the
/// artifact once, then serves jobs until the channel closes.
fn xla_owner_loop(artifacts_dir: std::path::PathBuf, artifact: String, rx: Receiver<XlaJob>) {
    let exe = Runtime::cpu(&artifacts_dir)
        .map_err(|e| e.to_string())
        .and_then(|runtime| {
            if runtime.has_artifact(&artifact) {
                runtime.load(&artifact).map_err(|e| e.to_string())
            } else {
                Err(format!("artifact {artifact} missing (run `make artifacts`)"))
            }
        });
    while let Ok(job) = rx.recv() {
        let result = match &exe {
            Ok(exe) => exe
                .run_f32(&[(&[KERNEL_BATCH, 2], &job.xs), (&[4, 4], &job.w)])
                .map(|mut out| out.remove(0))
                .map_err(|e| e.to_string()),
            Err(e) => Err(e.clone()),
        };
        let _ = job.reply.send(result);
    }
}

/// Batch size the AOT kernel was lowered with (see python/compile/aot.py).
const KERNEL_BATCH: usize = 1024;

/// The running evaluation service.
pub struct EvalServer {
    tx: Option<Sender<EvalRequest>>,
    shared: Arc<Shared>,
    batcher: Option<std::thread::JoinHandle<()>>,
    workers: Vec<std::thread::JoinHandle<()>>,
}

impl EvalServer {
    /// Start the service with a set of synthesized functions.
    /// `artifacts_dir` is optional: without it (or without artifacts) the
    /// XLA engine reports an error response instead of failing at startup.
    pub fn start(
        functions: Vec<SmurfApproximator>,
        artifacts_dir: Option<std::path::PathBuf>,
        cfg: ServerConfig,
    ) -> Self {
        // Dedicated XLA owner thread (PJRT client is not Send).
        let xla_tx = artifacts_dir.map(|dir| {
            let (jtx, jrx) = channel::<XlaJob>();
            let artifact = cfg.xla_artifact.clone();
            std::thread::Builder::new()
                .name("smurf-xla".into())
                .spawn(move || xla_owner_loop(dir, artifact, jrx))
                .expect("spawn xla owner");
            jtx
        });
        let shared = Arc::new(Shared {
            functions: functions
                .into_iter()
                .map(|f| (f.name().to_string(), Arc::new(f)))
                .collect(),
            metrics: Metrics::new(),
            xla_tx,
        });
        let (tx, rx) = channel::<EvalRequest>();
        let (btx, brx) = channel::<Batch>();
        let policy = cfg.policy;
        let batcher = std::thread::Builder::new()
            .name("smurf-batcher".into())
            .spawn(move || run_batcher(rx, btx, policy))
            .expect("spawn batcher");
        // Work-stealing via a shared locked receiver.
        let brx = Arc::new(Mutex::new(brx));
        let mut workers = Vec::new();
        for i in 0..cfg.workers.max(1) {
            let shared = shared.clone();
            let brx = brx.clone();
            workers.push(
                std::thread::Builder::new()
                    .name(format!("smurf-worker-{i}"))
                    .spawn(move || worker_loop(shared, brx))
                    .expect("spawn worker"),
            );
        }
        Self { tx: Some(tx), shared, batcher: Some(batcher), workers }
    }

    /// Submit a request. Returns an error if the server is stopped.
    pub fn submit(&self, mut req: EvalRequest) -> Result<(), String> {
        req.enqueued = Instant::now();
        self.tx
            .as_ref()
            .ok_or("server stopped")?
            .send(req)
            .map_err(|_| "server channel closed".to_string())
    }

    /// Convenience: synchronous single-request evaluation.
    pub fn eval_sync(
        &self,
        function: &str,
        points: Vec<Vec<f64>>,
        engine: Engine,
        stream_len: usize,
    ) -> EvalResponse {
        let (rtx, rrx) = channel();
        let req = EvalRequest {
            function: function.to_string(),
            points,
            engine,
            stream_len,
            enqueued: Instant::now(),
            reply: rtx,
        };
        if let Err(e) = self.submit(req) {
            return EvalResponse::failed(e);
        }
        rrx.recv().unwrap_or_else(|_| EvalResponse::failed("worker dropped reply"))
    }

    /// Metrics snapshot.
    pub fn metrics(&self) -> super::metrics::Snapshot {
        self.shared.metrics.snapshot()
    }

    /// Registered function names.
    pub fn functions(&self) -> Vec<String> {
        let mut v: Vec<String> = self.shared.functions.keys().cloned().collect();
        v.sort();
        v
    }

    /// Graceful shutdown: close intake, join batcher and workers.
    pub fn shutdown(mut self) {
        self.tx.take(); // closes the channel; batcher drains and exits
        if let Some(b) = self.batcher.take() {
            let _ = b.join();
        }
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

fn worker_loop(shared: Arc<Shared>, brx: Arc<Mutex<Receiver<Batch>>>) {
    loop {
        let batch = {
            let guard = brx.lock().unwrap();
            guard.recv()
        };
        let Ok(batch) = batch else { return };
        execute_batch(&shared, batch);
    }
}

fn execute_batch(shared: &Shared, batch: Batch) {
    let (ref fname, engine) = batch.key;
    let batch_size = batch.requests.len();
    let Some(func) = shared.functions.get(fname).cloned() else {
        for req in batch.requests {
            shared.metrics.record_error();
            let _ = req.reply.send(EvalResponse::failed(format!("unknown function {fname}")));
        }
        return;
    };

    // Flatten points across requests, execute once, scatter results.
    let spans: Vec<usize> = batch.requests.iter().map(|r| r.points.len()).collect();
    let all_points: Vec<&[f64]> = batch
        .requests
        .iter()
        .flat_map(|r| r.points.iter().map(|p| p.as_slice()))
        .collect();

    let exec_start = Instant::now();
    let result: Result<Vec<f64>, String> = match engine {
        Engine::Analytic => Ok(all_points.iter().map(|p| func.eval_analytic(p)).collect()),
        Engine::BitLevel => {
            let len = batch.requests.first().map(|r| r.stream_len.max(1)).unwrap_or(64);
            Ok(eval_bitlevel_batch(&func, &all_points, len))
        }
        Engine::Xla => execute_xla(shared, &func, &all_points),
    };
    let exec_ns = exec_start.elapsed().as_nanos() as u64;

    match result {
        Ok(outputs) => {
            let mut off = 0;
            for (req, span) in batch.requests.into_iter().zip(spans) {
                let queue_ns = batch
                    .formed_at
                    .saturating_duration_since(req.enqueued)
                    .as_nanos() as u64;
                let e2e_ns = req.enqueued.elapsed().as_nanos() as u64;
                shared.metrics.record(queue_ns, exec_ns, e2e_ns, span as u64, off == 0);
                let _ = req.reply.send(EvalResponse {
                    outputs: outputs[off..off + span].to_vec(),
                    queue_ns,
                    exec_ns,
                    batch_size,
                    error: None,
                });
                off += span;
            }
        }
        Err(e) => {
            for req in batch.requests {
                shared.metrics.record_error();
                let _ = req.reply.send(EvalResponse::failed(e.clone()));
            }
        }
    }
}

/// Points per wide pass (one trial per bit lane of a `u64` word).
const WIDE_LANES: usize = crate::smurf::sim_wide::LANES;

/// Batch size at which the bit-level engine switches from per-point scalar
/// simulation to the bit-sliced wide engine; below this the fixed 64-lane
/// word cost is not amortized (same threshold as the estimator routing).
const WIDE_BATCH_MIN: usize = crate::smurf::sim::WIDE_TRIALS_MIN;

/// Bit-level engine over a flattened batch: chunk the points into 64-lane
/// words and run each chunk through the wide simulator (each lane is one
/// point of the batch). Per-point outputs are bit-exact equal to the
/// scalar `eval_bitstream(p, len, 0x5EED ^ i)` this replaces, so clients
/// observe identical streams regardless of batch size.
fn eval_bitlevel_batch(
    func: &SmurfApproximator,
    points: &[&[f64]],
    len: usize,
) -> Vec<f64> {
    if points.len() < WIDE_BATCH_MIN {
        return points
            .iter()
            .enumerate()
            .map(|(i, p)| func.eval_bitstream(p, len, 0x5EED ^ i as u64))
            .collect();
    }
    let wide = func.wide_simulator();
    let mut st = wide.make_run_state();
    let mut outputs = vec![0.0f64; points.len()];
    let mut seeds = [0u64; WIDE_LANES];
    let mut lane_out = [0.0f64; WIDE_LANES];
    for (c, chunk) in points.chunks(WIDE_LANES).enumerate() {
        for (k, s) in seeds.iter_mut().enumerate().take(chunk.len()) {
            *s = 0x5EED ^ (c * WIDE_LANES + k) as u64;
        }
        wide.eval_points(chunk, len, &seeds[..chunk.len()], &mut st, &mut lane_out);
        outputs[c * WIDE_LANES..c * WIDE_LANES + chunk.len()]
            .copy_from_slice(&lane_out[..chunk.len()]);
    }
    outputs
}

/// Execute a batch on the AOT XLA kernel via the owner thread. The
/// shipped kernel is specialized to M=2/N=4 with a runtime coefficient
/// table and a fixed batch of 1024 (padded).
fn execute_xla(
    shared: &Shared,
    func: &SmurfApproximator,
    points: &[&[f64]],
) -> Result<Vec<f64>, String> {
    let jtx = shared.xla_tx.as_ref().ok_or("XLA runtime not configured")?;
    if func.config().num_vars() != 2 || func.config().radices() != [4, 4] {
        return Err("XLA kernel is compiled for bivariate N=4 functions".into());
    }
    let w: Vec<f32> = func.coefficients().iter().map(|&x| x as f32).collect();
    let mut outputs = Vec::with_capacity(points.len());
    for chunk in points.chunks(KERNEL_BATCH) {
        let mut xs = vec![0.0f32; KERNEL_BATCH * 2];
        for (i, p) in chunk.iter().enumerate() {
            xs[i * 2] = p[0] as f32;
            xs[i * 2 + 1] = p[1] as f32;
        }
        let (rtx, rrx) = channel();
        jtx.send(XlaJob { xs, w: w.clone(), reply: rtx })
            .map_err(|_| "xla owner thread gone".to_string())?;
        let out = rrx.recv().map_err(|_| "xla owner dropped reply".to_string())??;
        outputs.extend(out[..chunk.len()].iter().map(|&y| y as f64));
    }
    Ok(outputs)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::smurf::config::SmurfConfig;
    use crate::synth::functions;

    fn test_server(workers: usize) -> EvalServer {
        let cfg = SmurfConfig::uniform(2, 4);
        let funcs = vec![
            SmurfApproximator::synthesize(&cfg, &functions::euclidean2(), 64),
            SmurfApproximator::synthesize(&cfg, &functions::product2(), 64),
        ];
        EvalServer::start(
            funcs,
            None,
            ServerConfig {
                workers,
                policy: BatchPolicy {
                    max_batch: 8,
                    max_wait: std::time::Duration::from_millis(1),
                },
                xla_artifact: "smurf_eval.hlo.txt".into(),
            },
        )
    }

    #[test]
    fn serves_analytic_requests() {
        let server = test_server(2);
        let resp = server.eval_sync("euclidean2", vec![vec![0.3, 0.4]], Engine::Analytic, 64);
        assert!(resp.is_ok(), "{:?}", resp.error);
        assert!((resp.outputs[0] - 0.5).abs() < 0.05, "y={}", resp.outputs[0]);
        server.shutdown();
    }

    #[test]
    fn serves_bitlevel_requests() {
        let server = test_server(2);
        let resp = server.eval_sync("product2", vec![vec![0.5, 0.5]], Engine::BitLevel, 256);
        assert!(resp.is_ok());
        assert!((resp.outputs[0] - 0.25).abs() < 0.2, "y={}", resp.outputs[0]);
        server.shutdown();
    }

    #[test]
    fn bitlevel_batch_matches_scalar_per_point() {
        // The wide 64-lane batch path must reproduce the per-point scalar
        // streams bit-exactly (same 0x5EED ^ i seeds), across the chunk
        // boundary at 64 and the scalar fallback below 8 points.
        let server = test_server(1);
        let cfg = SmurfConfig::uniform(2, 4);
        let reference =
            SmurfApproximator::synthesize(&cfg, &functions::euclidean2(), 64);
        for n in [3usize, 8, 64, 70] {
            let points: Vec<Vec<f64>> = (0..n)
                .map(|i| vec![(i % 9) as f64 / 8.0, (i % 7) as f64 / 6.0])
                .collect();
            let resp = server.eval_sync("euclidean2", points.clone(), Engine::BitLevel, 128);
            assert!(resp.is_ok(), "{:?}", resp.error);
            assert_eq!(resp.outputs.len(), n);
            for (i, p) in points.iter().enumerate() {
                let expect = reference.eval_bitstream(p, 128, 0x5EED ^ i as u64);
                assert_eq!(resp.outputs[i], expect, "n={n} point {i}");
            }
        }
        server.shutdown();
    }

    #[test]
    fn unknown_function_errors() {
        let server = test_server(1);
        let resp = server.eval_sync("nope", vec![vec![0.1, 0.1]], Engine::Analytic, 64);
        assert!(!resp.is_ok());
        assert_eq!(server.metrics().errors, 1);
        server.shutdown();
    }

    #[test]
    fn xla_without_runtime_errors_cleanly() {
        let server = test_server(1);
        let resp = server.eval_sync("euclidean2", vec![vec![0.1, 0.1]], Engine::Xla, 64);
        assert!(!resp.is_ok());
        server.shutdown();
    }

    #[test]
    fn concurrent_load_is_batched() {
        let server = Arc::new(test_server(4));
        let mut handles = Vec::new();
        for t in 0..8 {
            let s = server.clone();
            handles.push(std::thread::spawn(move || {
                for i in 0..25 {
                    let x = (t as f64 * 25.0 + i as f64) / 200.0;
                    let r = s.eval_sync("euclidean2", vec![vec![x, x]], Engine::Analytic, 64);
                    assert!(r.is_ok());
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        let snap = server.metrics().clone();
        assert_eq!(snap.requests, 200);
        assert!(snap.mean_batch_size >= 1.0);
        assert_eq!(snap.errors, 0);
        Arc::try_unwrap(server).ok().map(|s| s.shutdown());
    }

    #[test]
    fn functions_listing() {
        let server = test_server(1);
        assert_eq!(server.functions(), vec!["euclidean2", "product2"]);
        server.shutdown();
    }
}

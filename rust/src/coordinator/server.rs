//! The evaluation server: function registry + batcher + worker pool.
//!
//! Architecture (std threads + channels; Python never on this path):
//!
//! ```text
//! clients → submit() → [mpsc] → batcher thread → [mpsc] → N workers
//!                                                     ↘ metrics
//! ```
//!
//! Workers execute a whole batch on one engine: the bit-level simulator,
//! the analytic evaluator, or — when `artifacts/smurf_eval.hlo.txt`
//! exists — the AOT-compiled XLA kernel for supported configurations.

use super::batcher::{run_batcher, Batch, BatchPolicy};
use super::metrics::Metrics;
use super::request::{Engine, EvalRequest, EvalResponse};
use crate::runtime::Runtime;
use crate::smurf::approximator::SmurfApproximator;
use std::collections::HashMap;
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Mutex};
use std::time::Instant;

/// Server configuration.
#[derive(Clone, Debug)]
pub struct ServerConfig {
    pub workers: usize,
    pub policy: BatchPolicy,
    /// Artifact name of the XLA smurf_eval kernel (batch-N, M=2, N=4).
    pub xla_artifact: String,
}

impl Default for ServerConfig {
    fn default() -> Self {
        Self {
            workers: std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4).min(8),
            policy: BatchPolicy::default(),
            xla_artifact: "smurf_eval.hlo.txt".into(),
        }
    }
}

/// A job for the dedicated XLA thread (the PJRT client is not `Send` in
/// the `xla` crate, so a single owner thread serializes device access —
/// the same single-queue model a real accelerator backend uses).
struct XlaJob {
    /// Row-major (batch, 2) f32 inputs, padded to the kernel batch.
    xs: Vec<f32>,
    /// 4×4 coefficient table.
    w: Vec<f32>,
    reply: Sender<Result<Vec<f32>, String>>,
}

/// Shared state between workers.
struct Shared {
    functions: HashMap<String, Arc<SmurfApproximator>>,
    metrics: Metrics,
    xla_tx: Option<Sender<XlaJob>>,
}

/// Owner loop for the PJRT runtime: creates the client *inside* the
/// thread (the `xla` crate's handles are not `Send`), compiles the
/// artifact once, then serves jobs until the channel closes.
fn xla_owner_loop(artifacts_dir: std::path::PathBuf, artifact: String, rx: Receiver<XlaJob>) {
    let exe = Runtime::cpu(&artifacts_dir)
        .map_err(|e| e.to_string())
        .and_then(|runtime| {
            if runtime.has_artifact(&artifact) {
                runtime.load(&artifact).map_err(|e| e.to_string())
            } else {
                Err(format!("artifact {artifact} missing (run `make artifacts`)"))
            }
        });
    while let Ok(job) = rx.recv() {
        let result = match &exe {
            Ok(exe) => exe
                .run_f32(&[(&[KERNEL_BATCH, 2], &job.xs), (&[4, 4], &job.w)])
                .map(|mut out| out.remove(0))
                .map_err(|e| e.to_string()),
            Err(e) => Err(e.clone()),
        };
        let _ = job.reply.send(result);
    }
}

/// Batch size the AOT kernel was lowered with (see python/compile/aot.py).
const KERNEL_BATCH: usize = 1024;

/// The running evaluation service.
pub struct EvalServer {
    tx: Option<Sender<EvalRequest>>,
    shared: Arc<Shared>,
    batcher: Option<std::thread::JoinHandle<()>>,
    workers: Vec<std::thread::JoinHandle<()>>,
}

impl EvalServer {
    /// Start the service with a set of synthesized functions.
    /// `artifacts_dir` is optional: without it (or without artifacts) the
    /// XLA engine reports an error response instead of failing at startup.
    pub fn start(
        functions: Vec<SmurfApproximator>,
        artifacts_dir: Option<std::path::PathBuf>,
        cfg: ServerConfig,
    ) -> Self {
        // Dedicated XLA owner thread (PJRT client is not Send).
        let xla_tx = artifacts_dir.map(|dir| {
            let (jtx, jrx) = channel::<XlaJob>();
            let artifact = cfg.xla_artifact.clone();
            std::thread::Builder::new()
                .name("smurf-xla".into())
                .spawn(move || xla_owner_loop(dir, artifact, jrx))
                .expect("spawn xla owner");
            jtx
        });
        let shared = Arc::new(Shared {
            functions: functions
                .into_iter()
                .map(|f| (f.name().to_string(), Arc::new(f)))
                .collect(),
            metrics: Metrics::new(),
            xla_tx,
        });
        let (tx, rx) = channel::<EvalRequest>();
        let (btx, brx) = channel::<Batch>();
        let policy = cfg.policy;
        let batcher = std::thread::Builder::new()
            .name("smurf-batcher".into())
            .spawn(move || run_batcher(rx, btx, policy))
            .expect("spawn batcher");
        // Work-stealing via a shared locked receiver.
        let brx = Arc::new(Mutex::new(brx));
        let mut workers = Vec::new();
        for i in 0..cfg.workers.max(1) {
            let shared = shared.clone();
            let brx = brx.clone();
            workers.push(
                std::thread::Builder::new()
                    .name(format!("smurf-worker-{i}"))
                    .spawn(move || worker_loop(shared, brx))
                    .expect("spawn worker"),
            );
        }
        Self { tx: Some(tx), shared, batcher: Some(batcher), workers }
    }

    /// Submit a request. Returns an error if the server is stopped.
    pub fn submit(&self, mut req: EvalRequest) -> Result<(), String> {
        req.enqueued = Instant::now();
        self.tx
            .as_ref()
            .ok_or("server stopped")?
            .send(req)
            .map_err(|_| "server channel closed".to_string())
    }

    /// Convenience: synchronous single-request evaluation.
    pub fn eval_sync(
        &self,
        function: &str,
        points: Vec<Vec<f64>>,
        engine: Engine,
        stream_len: usize,
    ) -> EvalResponse {
        let (rtx, rrx) = channel();
        let req = EvalRequest {
            function: function.to_string(),
            points,
            engine,
            stream_len,
            enqueued: Instant::now(),
            reply: rtx,
        };
        if let Err(e) = self.submit(req) {
            return EvalResponse::failed(e);
        }
        rrx.recv().unwrap_or_else(|_| EvalResponse::failed("worker dropped reply"))
    }

    /// Metrics snapshot.
    pub fn metrics(&self) -> super::metrics::Snapshot {
        self.shared.metrics.snapshot()
    }

    /// Registered function names.
    pub fn functions(&self) -> Vec<String> {
        let mut v: Vec<String> = self.shared.functions.keys().cloned().collect();
        v.sort();
        v
    }

    /// Graceful shutdown: close intake, join batcher and workers.
    pub fn shutdown(mut self) {
        self.tx.take(); // closes the channel; batcher drains and exits
        if let Some(b) = self.batcher.take() {
            let _ = b.join();
        }
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

fn worker_loop(shared: Arc<Shared>, brx: Arc<Mutex<Receiver<Batch>>>) {
    loop {
        let batch = {
            let guard = brx.lock().unwrap();
            guard.recv()
        };
        let Ok(batch) = batch else { return };
        execute_batch(&shared, batch);
    }
}

fn execute_batch(shared: &Shared, batch: Batch) {
    let (ref fname, engine) = batch.key;
    let batch_size = batch.requests.len();
    let Some(func) = shared.functions.get(fname).cloned() else {
        for req in batch.requests {
            shared.metrics.record_error();
            let _ = req.reply.send(EvalResponse::failed(format!("unknown function {fname}")));
        }
        return;
    };

    // Execute the whole batch once, then scatter results per request.
    // (The BitLevel engine works on the request structure directly —
    // stream lengths and seeds are per-request — so only the engines
    // that are length-agnostic flatten the points.)
    let spans: Vec<usize> = batch.requests.iter().map(|r| r.points.len()).collect();
    let exec_start = Instant::now();
    let result: Result<Vec<f64>, String> = match engine {
        Engine::Analytic => Ok(batch
            .requests
            .iter()
            .flat_map(|r| r.points.iter())
            .map(|p| func.eval_analytic(p))
            .collect()),
        Engine::BitLevel => Ok(eval_bitlevel_batch(&func, &batch.requests)),
        Engine::Xla => {
            let all_points: Vec<&[f64]> = batch
                .requests
                .iter()
                .flat_map(|r| r.points.iter().map(|p| p.as_slice()))
                .collect();
            execute_xla(shared, &func, &all_points)
        }
    };
    let exec_ns = exec_start.elapsed().as_nanos() as u64;

    match result {
        Ok(outputs) => {
            let mut off = 0;
            for (req, span) in batch.requests.into_iter().zip(spans) {
                let queue_ns = batch
                    .formed_at
                    .saturating_duration_since(req.enqueued)
                    .as_nanos() as u64;
                let e2e_ns = req.enqueued.elapsed().as_nanos() as u64;
                shared.metrics.record(queue_ns, exec_ns, e2e_ns, span as u64, off == 0);
                let _ = req.reply.send(EvalResponse {
                    outputs: outputs[off..off + span].to_vec(),
                    queue_ns,
                    exec_ns,
                    batch_size,
                    error: None,
                });
                off += span;
            }
        }
        Err(e) => {
            for req in batch.requests {
                shared.metrics.record_error();
                let _ = req.reply.send(EvalResponse::failed(e.clone()));
            }
        }
    }
}

/// Points per wide pass: one trial per lane of the widest bit plane
/// compiled into the build (256, or 512 with the `wide512` feature).
const WIDE_LANES: usize = crate::smurf::sim_wide::MAX_LANES;

/// Batch size at which the bit-level engine switches from per-point scalar
/// simulation to the bit-sliced wide engine; below this the fixed lane
/// word cost is not amortized (same threshold as the estimator routing).
const WIDE_BATCH_MIN: usize = crate::smurf::sim::WIDE_TRIALS_MIN;

/// Bit-level engine over a batch of requests, flattened in request order.
///
/// Two batching guarantees the previous flattened-slice implementation
/// broke, both load-bearing for a deterministic service:
///
/// - **Per-request stream lengths.** Points are grouped by `stream_len`
///   before chunking, so a mixed-L batch evaluates every request at *its
///   own* L instead of the first request's (and the groups run
///   independently — no serialization on the first request's length).
/// - **Batch-independent streams.** Seeds derive from the point's index
///   *within its request* (`0x5EED ^ i`), not its slot in the flattened
///   batch, so a client observes the same bitstream for the same request
///   regardless of what it was batched with.
///
/// Points run through [`SmurfApproximator::eval_bitstream_points_into`]
/// — [`WIDE_LANES`] lanes per wide pass (the widest plane in the build),
/// points from different requests sharing passes, on the calling worker's
/// persistent thread-local
/// [`WideRunState`](crate::smurf::sim_wide::WideRunState) scratch.
/// The dominant uniform-L batch streams lanes directly and allocates only
/// the output vector; a mixed-L batch additionally builds small
/// per-length index lists so each group chunks independently. Per-point
/// outputs stay bit-exact equal to the scalar
/// `eval_bitstream(p, len, 0x5EED ^ i)` at every plane width.
fn eval_bitlevel_batch(func: &SmurfApproximator, requests: &[EvalRequest]) -> Vec<f64> {
    let total: usize = requests.iter().map(|r| r.points.len()).sum();
    let mut outputs = vec![0.0f64; total];

    // Fast path: every request shares one stream length (the common case
    // — the batcher keys on function+engine, and clients of one function
    // typically agree on L). Slots are then contiguous in flattened
    // order, so lanes stream straight into the output vector with no
    // grouping structures at all.
    let uniform_len = {
        let mut lens = requests.iter().map(|r| r.stream_len.max(1));
        let first = lens.next();
        first.filter(|&l| lens.all(|x| x == l))
    };
    if let Some(len) = uniform_len {
        if total < WIDE_BATCH_MIN {
            // Below this the fixed plane-word cost is not amortized
            // (small wide-eligible batches route to the 64-lane engine
            // inside eval_bitstream_points_into).
            let mut slot = 0usize;
            for r in requests {
                for (i, p) in r.points.iter().enumerate() {
                    outputs[slot] = func.eval_bitstream(p, len, 0x5EED ^ i as u64);
                    slot += 1;
                }
            }
            return outputs;
        }
        let mut pts: [&[f64]; WIDE_LANES] = [&[]; WIDE_LANES];
        let mut seeds = [0u64; WIDE_LANES];
        let mut lane_out = [0.0f64; WIDE_LANES];
        let mut fill = 0usize;
        let mut flushed = 0usize;
        for r in requests {
            for (i, p) in r.points.iter().enumerate() {
                pts[fill] = p.as_slice();
                seeds[fill] = 0x5EED ^ i as u64;
                fill += 1;
                if fill == WIDE_LANES {
                    func.eval_bitstream_points_into(&pts, len, &seeds, &mut lane_out);
                    outputs[flushed..flushed + WIDE_LANES].copy_from_slice(&lane_out);
                    flushed += WIDE_LANES;
                    fill = 0;
                }
            }
        }
        if fill > 0 {
            func.eval_bitstream_points_into(
                &pts[..fill],
                len,
                &seeds[..fill],
                &mut lane_out[..fill],
            );
            outputs[flushed..flushed + fill].copy_from_slice(&lane_out[..fill]);
        }
        return outputs;
    }

    // Mixed-L batch: group (flattened output slot, seed, point) by stream
    // length so every request evaluates at its own L.
    let mut groups: std::collections::BTreeMap<usize, Vec<(usize, u64, &[f64])>> =
        std::collections::BTreeMap::new();
    let mut off = 0usize;
    for r in requests {
        let len = r.stream_len.max(1);
        let group = groups.entry(len).or_default();
        for (i, p) in r.points.iter().enumerate() {
            group.push((off + i, 0x5EED ^ i as u64, p.as_slice()));
        }
        off += r.points.len();
    }
    for (len, entries) in &groups {
        if entries.len() < WIDE_BATCH_MIN {
            for &(slot, seed, p) in entries {
                outputs[slot] = func.eval_bitstream(p, *len, seed);
            }
            continue;
        }
        // The group is already heap-materialized, so hand the whole thing
        // to the approximator (which owns the 64-lane chunking) and
        // scatter the results to their flattened slots.
        let gpts: Vec<&[f64]> = entries.iter().map(|&(_, _, p)| p).collect();
        let gseeds: Vec<u64> = entries.iter().map(|&(_, s, _)| s).collect();
        let gout = func.eval_bitstream_points(&gpts, *len, &gseeds);
        for (&(slot, _, _), y) in entries.iter().zip(gout) {
            outputs[slot] = y;
        }
    }
    outputs
}

/// Execute a batch on the AOT XLA kernel via the owner thread. The
/// shipped kernel is specialized to M=2/N=4 with a runtime coefficient
/// table and a fixed batch of 1024 (padded).
fn execute_xla(
    shared: &Shared,
    func: &SmurfApproximator,
    points: &[&[f64]],
) -> Result<Vec<f64>, String> {
    let jtx = shared.xla_tx.as_ref().ok_or("XLA runtime not configured")?;
    if func.config().num_vars() != 2 || func.config().radices() != [4, 4] {
        return Err("XLA kernel is compiled for bivariate N=4 functions".into());
    }
    let w: Vec<f32> = func.coefficients().iter().map(|&x| x as f32).collect();
    let mut outputs = Vec::with_capacity(points.len());
    for chunk in points.chunks(KERNEL_BATCH) {
        let mut xs = vec![0.0f32; KERNEL_BATCH * 2];
        for (i, p) in chunk.iter().enumerate() {
            xs[i * 2] = p[0] as f32;
            xs[i * 2 + 1] = p[1] as f32;
        }
        let (rtx, rrx) = channel();
        jtx.send(XlaJob { xs, w: w.clone(), reply: rtx })
            .map_err(|_| "xla owner thread gone".to_string())?;
        let out = rrx.recv().map_err(|_| "xla owner dropped reply".to_string())??;
        outputs.extend(out[..chunk.len()].iter().map(|&y| y as f64));
    }
    Ok(outputs)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::smurf::config::SmurfConfig;
    use crate::synth::functions;

    fn test_server(workers: usize) -> EvalServer {
        let cfg = SmurfConfig::uniform(2, 4);
        let funcs = vec![
            SmurfApproximator::synthesize(&cfg, &functions::euclidean2(), 64),
            SmurfApproximator::synthesize(&cfg, &functions::product2(), 64),
        ];
        EvalServer::start(
            funcs,
            None,
            ServerConfig {
                workers,
                policy: BatchPolicy {
                    max_batch: 8,
                    max_wait: std::time::Duration::from_millis(1),
                },
                xla_artifact: "smurf_eval.hlo.txt".into(),
            },
        )
    }

    #[test]
    fn serves_analytic_requests() {
        let server = test_server(2);
        let resp = server.eval_sync("euclidean2", vec![vec![0.3, 0.4]], Engine::Analytic, 64);
        assert!(resp.is_ok(), "{:?}", resp.error);
        assert!((resp.outputs[0] - 0.5).abs() < 0.05, "y={}", resp.outputs[0]);
        server.shutdown();
    }

    #[test]
    fn serves_bitlevel_requests() {
        let server = test_server(2);
        let resp = server.eval_sync("product2", vec![vec![0.5, 0.5]], Engine::BitLevel, 256);
        assert!(resp.is_ok());
        assert!((resp.outputs[0] - 0.25).abs() < 0.2, "y={}", resp.outputs[0]);
        server.shutdown();
    }

    #[test]
    fn bitlevel_batch_matches_scalar_per_point() {
        // The wide batch path must reproduce the per-point scalar streams
        // bit-exactly (same 0x5EED ^ i seeds), across the u64-word mark
        // at 64, the auto-width chunk boundary at WIDE_LANES, and the
        // scalar fallback below 8 points.
        let server = test_server(1);
        let cfg = SmurfConfig::uniform(2, 4);
        let reference =
            SmurfApproximator::synthesize(&cfg, &functions::euclidean2(), 64);
        for n in [3usize, 8, 64, 70, WIDE_LANES, WIDE_LANES + 6] {
            let points: Vec<Vec<f64>> = (0..n)
                .map(|i| vec![(i % 9) as f64 / 8.0, (i % 7) as f64 / 6.0])
                .collect();
            let resp = server.eval_sync("euclidean2", points.clone(), Engine::BitLevel, 128);
            assert!(resp.is_ok(), "{:?}", resp.error);
            assert_eq!(resp.outputs.len(), n);
            for (i, p) in points.iter().enumerate() {
                let expect = reference.eval_bitstream(p, 128, 0x5EED ^ i as u64);
                assert_eq!(resp.outputs[i], expect, "n={n} point {i}");
            }
        }
        server.shutdown();
    }

    #[test]
    fn mixed_stream_lengths_evaluate_at_their_own_length() {
        // A batch mixing stream lengths must evaluate every request at
        // its own L (the old flattened path ran everything at the first
        // request's L), with seeds from the within-request point index.
        // Group shapes: len=32 gets 10 + (WIDE_LANES + 20) points — the
        // cross-request lane packing fills one whole plane word and
        // spills a tail past the auto-width chunk boundary — while
        // len=128 gets 3 (scalar fallback).
        let cfg = SmurfConfig::uniform(2, 4);
        let func = SmurfApproximator::synthesize(&cfg, &functions::euclidean2(), 64);
        let mk = |n: usize, len: usize, salt: usize| -> EvalRequest {
            let (rtx, _rrx) = channel();
            EvalRequest {
                function: "euclidean2".into(),
                points: (0..n)
                    .map(|i| vec![((i + salt) % 10) as f64 / 9.0, (i % 7) as f64 / 6.0])
                    .collect(),
                engine: Engine::BitLevel,
                stream_len: len,
                enqueued: Instant::now(),
                reply: rtx,
            }
        };
        let reqs = vec![mk(10, 32, 1), mk(3, 128, 2), mk(WIDE_LANES + 20, 32, 3)];
        let out = eval_bitlevel_batch(&func, &reqs);
        assert_eq!(out.len(), WIDE_LANES + 33);
        let mut off = 0;
        for (ri, r) in reqs.iter().enumerate() {
            for (i, p) in r.points.iter().enumerate() {
                let want = func.eval_bitstream(p, r.stream_len, 0x5EED ^ i as u64);
                assert_eq!(out[off + i], want, "request {ri} point {i}");
            }
            off += r.points.len();
        }
    }

    #[test]
    fn uniform_length_multi_request_batch_streams_lanes() {
        // The uniform-L fast path: 50 + (WIDE_LANES - 30) + 1 points from
        // three requests stream through shared WIDE_LANES-wide passes
        // (one full flush + a 21-lane tail), each point still seeded by
        // its within-request index.
        let cfg = SmurfConfig::uniform(2, 4);
        let func = SmurfApproximator::synthesize(&cfg, &functions::product2(), 64);
        let mk = |n: usize, salt: usize| -> EvalRequest {
            let (rtx, _rrx) = channel();
            EvalRequest {
                function: "product2".into(),
                points: (0..n)
                    .map(|i| vec![((i + salt) % 8) as f64 / 7.0, (i % 5) as f64 / 4.0])
                    .collect(),
                engine: Engine::BitLevel,
                stream_len: 64,
                enqueued: Instant::now(),
                reply: rtx,
            }
        };
        let reqs = vec![mk(50, 0), mk(WIDE_LANES - 30, 5), mk(1, 9)];
        let out = eval_bitlevel_batch(&func, &reqs);
        assert_eq!(out.len(), WIDE_LANES + 21);
        let mut off = 0;
        for (ri, r) in reqs.iter().enumerate() {
            for (i, p) in r.points.iter().enumerate() {
                let want = func.eval_bitstream(p, 64, 0x5EED ^ i as u64);
                assert_eq!(out[off + i], want, "request {ri} point {i}");
            }
            off += r.points.len();
        }
    }

    #[test]
    fn unknown_function_errors() {
        let server = test_server(1);
        let resp = server.eval_sync("nope", vec![vec![0.1, 0.1]], Engine::Analytic, 64);
        assert!(!resp.is_ok());
        assert_eq!(server.metrics().errors, 1);
        server.shutdown();
    }

    #[test]
    fn xla_without_runtime_errors_cleanly() {
        let server = test_server(1);
        let resp = server.eval_sync("euclidean2", vec![vec![0.1, 0.1]], Engine::Xla, 64);
        assert!(!resp.is_ok());
        server.shutdown();
    }

    #[test]
    fn concurrent_load_is_batched() {
        let server = Arc::new(test_server(4));
        let mut handles = Vec::new();
        for t in 0..8 {
            let s = server.clone();
            handles.push(std::thread::spawn(move || {
                for i in 0..25 {
                    let x = (t as f64 * 25.0 + i as f64) / 200.0;
                    let r = s.eval_sync("euclidean2", vec![vec![x, x]], Engine::Analytic, 64);
                    assert!(r.is_ok());
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        let snap = server.metrics().clone();
        assert_eq!(snap.requests, 200);
        assert!(snap.mean_batch_size >= 1.0);
        assert_eq!(snap.errors, 0);
        if let Ok(s) = Arc::try_unwrap(server) {
            s.shutdown();
        }
    }

    #[test]
    fn functions_listing() {
        let server = test_server(1);
        assert_eq!(server.functions(), vec!["euclidean2", "product2"]);
        server.shutdown();
    }
}

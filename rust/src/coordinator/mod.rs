//! Evaluation service — the L3 serving layer.
//!
//! SMURF is a *function generator*: the natural serving shape is an
//! evaluation service that accepts nonlinear-function evaluation requests
//! and executes them on one of three engines: the bit-level hardware
//! simulator, the analytic evaluator, or an AOT-compiled XLA executable
//! (the L1 Pallas kernel lowered through L2 and loaded by [`crate::runtime`]).
//!
//! - [`request`] — typed requests/responses and the typed failure model.
//! - [`admission`] — bounded intake: validation at the submit edge,
//!   per-engine in-flight depth limits, and hysteresis-latched load
//!   shedding.
//! - [`batcher`] — dynamic batching with size + deadline triggers
//!   (vLLM-router-style): requests accumulate until `max_batch` or
//!   `max_wait` elapses, then the batch is dispatched to a worker.
//! - [`server`] — supervised worker pool wiring it together over std
//!   threads + channels (tokio is not vendored in this offline
//!   environment).
//! - [`metrics`] — latency histograms + throughput and fault counters.
//! - [`fault`] — injection hooks used by the chaos test suite.
//! - [`sentinel`] — drift detection: canary cross-checks of bit-level
//!   responses against the analytic closed form, per-function error
//!   EWMAs, and the quarantine state machine.
//! - [`client`] — the caller-side recovery ladder: deadline-carving
//!   retries with token-bucket budgets, hedged requests with
//!   bit-identity audits, and per-function circuit breakers.
//!
//! # Failure model
//!
//! Two fault classes are handled, at different layers:
//!
//! - **Process-level faults** (panics, stalls, overload, shutdown
//!   races) — threads die loudly and the items below guarantee every
//!   client still gets a typed answer.
//! - **Bit-level faults** (stuck-at/transient upsets inside the
//!   stochastic engine — see [`crate::sc::fault`]) — these do *not*
//!   crash anything; they silently skew outputs. The serving layer
//!   detects them semantically: the analytic evaluator never touches the
//!   stochastic pipeline, so it is a fault-free reference, and the drift
//!   sentinel cross-checks a paced fraction of `BitLevel` responses
//!   against it ([`sentinel`]). Non-finite engine outputs are caught by
//!   a worker-side guard and answered as typed `EvalError::Engine`
//!   errors, never returned as poisoned floats.
//!
//! The service's contract is that **every admitted request is answered
//! exactly once**, and every non-admitted request is refused with a typed
//! reason at the submit edge. The possible outcomes of a submit:
//!
//! - **Rejected** (`Err(EvalError::Rejected(_))` from `submit`, before
//!   anything queues):
//!   - `BadRequest` — unknown function, arity mismatch, non-finite
//!     input, or `stream_len == 0` on the bit-level engine;
//!   - `Deadline` — the request's deadline had already expired;
//!   - `QueueFull` — the target engine is at its in-flight limit
//!     (`AdmissionConfig::*_limit`) and, for `BitLevel`, shedding could
//!     not absorb the request either.
//! - **Degraded success** — under load shedding a `BitLevel` request is
//!   rewritten to the `Analytic` closed form (Eq. 21) and served
//!   immediately; the response carries `degraded: true`. Shedding
//!   engages at `shed_high` in-flight BitLevel requests and disengages
//!   at `shed_low` (hysteresis, so the policy cannot flap).
//! - **Deadline expiry in flight** — a request whose deadline fires
//!   while queued is answered with `Rejected(Deadline)` at batch
//!   formation or at the worker, never evaluated, never dropped.
//! - **Worker panic** — batches execute under `catch_unwind`; a panic
//!   answers every in-flight request of that batch with
//!   `WorkerPanic(reason)`, the worker thread exits (per-thread engine
//!   scratch may be mid-update), and the supervisor respawns a
//!   replacement, so the pool always returns to full strength. The
//!   batcher has the same restart guarantee.
//! - **Shutdown** — requests still queued when `shutdown()` closes
//!   intake are either evaluated by the draining workers or answered
//!   with `EvalError::Shutdown`; nothing is silently dropped.
//! - **Client timeout** — `eval_sync` always carries a deadline (the
//!   configured `sync_timeout` by default) and returns a typed
//!   `Timeout` if the reply does not arrive in time; it can never block
//!   forever.
//! - **Engine drift / quarantine** — per function, the sentinel runs
//!   `Healthy → Quarantined → Probing → Healthy`: a canary-error EWMA
//!   crossing its threshold raises a typed
//!   [`DriftAlarm`](sentinel::DriftAlarm) and quarantines the function
//!   (its `BitLevel` traffic degrades to the analytic closed form, same
//!   response shape as load shedding); every `probe_interval`-th request
//!   probes the real engine, and enough successful probes restore full
//!   service. A non-finite engine output is answered as a typed
//!   `EvalError::Engine` error.
//!
//! Determinism is preserved across all of this: a respawned worker
//! produces bit-identical BitLevel streams (seeds derive from the
//! request content, [`request::DEFAULT_STREAM_SEED`] `^` the
//! within-request point index, never from worker identity or batch
//! composition), and degraded responses are exactly the analytic
//! evaluation of the same coefficients.
//!
//! In-flight depth is accounted with RAII tokens attached at admission
//! and released on `Drop`, so no failure path — panic unwind, shutdown
//! drop, reply sent — can leak queue depth.
//!
//! The answered-exactly-once contract is mechanically audited: every
//! submit debits a `submitted` counter, every outcome above credits
//! exactly one answer bucket, and
//! [`metrics::Snapshot::check_conservation`] requires the ledger to
//! balance once the queues drain — checked at every chaos test's
//! teardown and, against *randomized* configurations and fault
//! schedules, by the seeded chaos soak ([`crate::testutil::soak`],
//! `make soak`; see docs/INVARIANTS.md § Randomized robustness
//! harness).
//!
//! # Client-side recovery taxonomy
//!
//! Everything above describes how the *server* fails; [`client`] is how
//! the *caller* recovers. Its ladder keys on one classification,
//! [`EvalError::is_retryable`](request::EvalError::is_retryable):
//!
//! - **Retryable** — `Timeout`, `Rejected(QueueFull)`, `WorkerPanic`,
//!   `Engine`: transient by construction (slow reply, momentary load,
//!   respawned worker, injected intermittent fault). A fresh identical
//!   attempt can win, and resubmission is *safe* because served outputs
//!   are deterministic per request (the seed-discipline note above).
//! - **Terminal** — `Rejected(BadRequest)`, `Rejected(Deadline)`,
//!   `Shutdown`, `CircuitOpen`: deterministic refusals or gone-forever
//!   states. Retrying cannot help and never burns budget.
//!
//! Recovery is then four independently configurable rungs
//! ([`client::ClientConfig`]):
//!
//! - **Retries** carve each attempt's timeout from one overall deadline
//!   and back off with equal-jitter drawn from a seeded
//!   [`crate::util::prng::Pcg`] stream — deterministic schedules, no
//!   `thread_rng`.
//! - **Budgets** are a token bucket (spend 1 per retry, earn a fraction
//!   per success) bounding retry amplification: a correlated outage
//!   costs at most `initial + earned` extra requests, never a storm.
//! - **Hedges** launch a second identical request after a latency
//!   threshold and take the first answer; the loser is audited for
//!   bit-identity with the winner when it lands (mismatch counters must
//!   stay 0 — that audit *is* the determinism invariant, exercised on
//!   live traffic).
//! - **Circuit breakers** are per-function `Closed → Open → HalfOpen`
//!   with count-based probe cadence (the sentinel's idiom); while open,
//!   callers get a typed [`EvalError::CircuitOpen`](request::EvalError)
//!   without the server ever seeing the request.
//!
//! With all four rungs disabled (the default config) the client is a
//! strict passthrough to
//! [`EvalServer::eval_sync_with_timeout`](server::EvalServer::eval_sync_with_timeout)
//! — byte-for-byte, pinned by the chaos suite.
//!
//! # Mechanically-enforced invariants
//!
//! The contracts above are not prose-only: `docs/INVARIANTS.md` (repo
//! root) catalogues every invariant of this module that a tool checks —
//! loom model checking of the concurrency kernels
//! (`rust/tests/loom_models.rs`, via the [`crate::util::sync`] facade),
//! the `xtask verify` static-analysis pass (no panicking calls in this
//! module's non-test code, seed-literal discipline, failure-mode docs),
//! clippy, the property suites, and the chaos suite — with pointers to
//! the checking layer for each.

pub mod admission;
pub mod batcher;
pub mod client;
pub mod fault;
pub mod metrics;
pub mod request;
pub mod sentinel;
pub mod server;

pub use admission::{Admission, AdmissionConfig};
pub use client::{
    BreakerConfig, BreakerState, BudgetConfig, ClientConfig, HedgeAudit, HedgeConfig, HedgeDelay,
    ResilientClient, RetryPolicy,
};
pub use fault::{FaultInjector, FlakyWindow};
pub use request::{Engine, EvalError, EvalRequest, EvalResponse, RejectReason, DEFAULT_STREAM_SEED};
pub use sentinel::{DriftAlarm, DriftSentinel, EngineHealth, SentinelConfig};
pub use server::{EvalServer, ServerConfig};

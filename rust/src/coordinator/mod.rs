//! Evaluation service — the L3 serving layer.
//!
//! SMURF is a *function generator*: the natural serving shape is an
//! evaluation service that accepts nonlinear-function evaluation requests
//! and executes them on one of three engines: the bit-level hardware
//! simulator, the analytic evaluator, or an AOT-compiled XLA executable
//! (the L1 Pallas kernel lowered through L2 and loaded by [`crate::runtime`]).
//!
//! - [`request`] — typed requests/responses.
//! - [`batcher`] — dynamic batching with size + deadline triggers
//!   (vLLM-router-style): requests accumulate until `max_batch` or
//!   `max_wait` elapses, then the batch is dispatched to a worker.
//! - [`server`] — worker pool wiring it together over std threads +
//!   channels (tokio is not vendored in this offline environment).
//! - [`metrics`] — latency histograms + throughput counters.

pub mod batcher;
pub mod metrics;
pub mod request;
pub mod server;

pub use request::{EvalRequest, EvalResponse, Engine};
pub use server::{EvalServer, ServerConfig};

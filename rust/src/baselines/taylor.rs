//! Taylor-series approximation baseline (paper §IV-C).
//!
//! The paper's hardware comparison expands the bivariate Euclidean
//! distance "to a cubic Taylor-series polynomial ... 16-bit datapath,
//! 4-stage pipeline". We implement a general multivariate Taylor
//! expansion around the box centre with fixed-point evaluation matching
//! that datapath, so both the accuracy equalization (MAE ≈ 0.015) and the
//! hardware inventory (multipliers/adders → Table VI) are derived from the
//! same object.

use crate::synth::functions::TargetFn;

/// A multivariate polynomial term: coefficient × Π x_j^{e_j}.
#[derive(Clone, Debug)]
pub struct Term {
    pub coeff: f64,
    pub exponents: Vec<u32>,
}

/// A multivariate Taylor polynomial around `center` up to total degree
/// `order`, with coefficients estimated by central finite differences.
#[derive(Clone, Debug)]
pub struct TaylorPoly {
    pub center: Vec<f64>,
    pub terms: Vec<Term>,
    pub order: u32,
}

impl TaylorPoly {
    /// Expand `f` around `center` to total degree `order`.
    ///
    /// Mixed partial derivatives are estimated with iterated central
    /// differences at step `h`; adequate for the smooth targets in play
    /// (error O(h²) per derivative, h = 1e-3 keeps rounding in check).
    pub fn expand(f: &TargetFn, center: &[f64], order: u32) -> Self {
        let m = center.len();
        assert_eq!(m, f.arity());
        let mut terms = Vec::new();
        let mut expo = vec![0u32; m];
        expand_rec(f, center, order, 0, &mut expo, &mut terms);
        Self { center: center.to_vec(), terms, order }
    }

    /// Exact (f64) evaluation.
    pub fn eval(&self, x: &[f64]) -> f64 {
        let mut y = 0.0;
        for t in &self.terms {
            let mut v = t.coeff;
            for (j, &e) in t.exponents.iter().enumerate() {
                for _ in 0..e {
                    v *= x[j] - self.center[j];
                }
            }
            y += v;
        }
        y
    }

    /// Fixed-point evaluation on a `frac_bits`-bit fractional datapath
    /// (the paper's 16-bit pipeline → `frac_bits = 14` leaves 2 integer
    /// bits of headroom for intermediate terms). Every product and sum is
    /// re-quantized, modeling truncation in the multiply-add array.
    pub fn eval_fixed(&self, x: &[f64], frac_bits: u32) -> f64 {
        let scale = (1u64 << frac_bits) as f64;
        let q = |v: f64| (v * scale).round() / scale;
        let mut y = 0.0;
        for t in &self.terms {
            let mut v = q(t.coeff);
            for (j, &e) in t.exponents.iter().enumerate() {
                let dx = q(x[j] - self.center[j]);
                for _ in 0..e {
                    v = q(v * dx);
                }
            }
            y = q(y + v);
        }
        y
    }

    /// Number of multiplications per evaluation (naive power evaluation:
    /// each term of total degree d costs d multiplies plus the coefficient
    /// multiply when d > 0) — what the Table VI hardware inventory counts.
    pub fn mul_count(&self) -> usize {
        self.terms
            .iter()
            .map(|t| {
                let d: u32 = t.exponents.iter().sum();
                if d == 0 {
                    0
                } else {
                    d as usize
                }
            })
            .sum()
    }

    /// Number of additions per evaluation (terms - 1, plus the dx
    /// subtractions).
    pub fn add_count(&self) -> usize {
        let subs: usize = self
            .terms
            .iter()
            .map(|t| t.exponents.iter().filter(|&&e| e > 0).count())
            .sum();
        self.terms.len().saturating_sub(1) + subs
    }

    /// Mean absolute error against the target over a uniform grid.
    pub fn mae_vs(&self, f: &TargetFn, grid: usize, frac_bits: Option<u32>) -> f64 {
        let m = self.center.len();
        let mut idx = vec![0usize; m];
        let mut x = vec![0.0; m];
        let mut total = 0.0;
        let mut count = 0usize;
        loop {
            for j in 0..m {
                x[j] = idx[j] as f64 / (grid - 1) as f64;
            }
            let y = match frac_bits {
                Some(fb) => self.eval_fixed(&x, fb),
                None => self.eval(&x),
            };
            total += (y - f.eval(&x)).abs();
            count += 1;
            let mut j = 0;
            loop {
                idx[j] += 1;
                if idx[j] < grid {
                    break;
                }
                idx[j] = 0;
                j += 1;
                if j == m {
                    return total / count as f64;
                }
            }
        }
    }
}

fn expand_rec(
    f: &TargetFn,
    center: &[f64],
    order: u32,
    j: usize,
    expo: &mut Vec<u32>,
    terms: &mut Vec<Term>,
) {
    let used: u32 = expo.iter().sum();
    if j == center.len() {
        let d = mixed_partial(f, center, expo);
        let fact: f64 = expo.iter().map(|&e| factorial(e)).product();
        let coeff = d / fact;
        if coeff.abs() > 1e-12 || used == 0 {
            terms.push(Term { coeff, exponents: expo.clone() });
        }
        return;
    }
    for e in 0..=(order - used) {
        expo[j] = e;
        expand_rec(f, center, order, j + 1, expo, terms);
    }
    expo[j] = 0;
}

fn factorial(n: u32) -> f64 {
    (1..=n).map(|k| k as f64).product::<f64>().max(1.0)
}

/// Iterated central difference for ∂^{|e|} f / Π ∂x_j^{e_j} at `center`.
fn mixed_partial(f: &TargetFn, center: &[f64], expo: &[u32]) -> f64 {
    const H: f64 = 1e-3;
    // Recursive: differentiate one variable at a time.
    fn rec(f: &TargetFn, x: &mut Vec<f64>, expo: &[u32], j: usize) -> f64 {
        if j == expo.len() {
            return f.eval(x);
        }
        let e = expo[j];
        if e == 0 {
            return rec(f, x, expo, j + 1);
        }
        // Central difference of order e via binomial stencil.
        let mut acc = 0.0;
        for k in 0..=e {
            let sign = if (e - k) % 2 == 0 { 1.0 } else { -1.0 };
            let binom = factorial(e) / (factorial(k) * factorial(e - k));
            let x0 = x[j];
            x[j] = x0 + (k as f64 - e as f64 / 2.0) * H;
            acc += sign * binom * rec(f, x, expo, j + 1);
            x[j] = x0;
        }
        acc / H.powi(e as i32)
    }
    let mut x = center.to_vec();
    rec(f, &mut x, expo, 0)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::synth::functions;

    #[test]
    fn expands_polynomial_exactly() {
        // f(x,y) = x*y is its own degree-2 expansion.
        let f = functions::product2();
        let p = TaylorPoly::expand(&f, &[0.5, 0.5], 2);
        for (x, y) in [(0.1, 0.9), (0.3, 0.3), (1.0, 0.0)] {
            let v = p.eval(&[x, y]);
            assert!((v - x * y).abs() < 1e-6, "({x},{y}): {v}");
        }
    }

    #[test]
    fn cubic_euclid_matches_paper_setup() {
        // Paper §IV-C: cubic expansion of sqrt(x1²+x2²), equalized to
        // MAE ≈ 0.015. Our grid MAE should land in the same regime
        // (the paper's exact interior region is unspecified; the function
        // is non-smooth at the origin so the global MAE is dominated by
        // the corner).
        let f = functions::euclidean2();
        let p = TaylorPoly::expand(&f, &[0.5, 0.5], 3);
        let mae = p.mae_vs(&f, 33, None);
        assert!(mae < 0.05, "cubic Euclid MAE={mae}");
        assert!(mae > 0.001, "suspiciously exact: {mae}");
    }

    #[test]
    fn fixed_point_close_to_float() {
        let f = functions::euclidean2();
        let p = TaylorPoly::expand(&f, &[0.5, 0.5], 3);
        let x = [0.3, 0.8];
        let a = p.eval(&x);
        let b = p.eval_fixed(&x, 14);
        assert!((a - b).abs() < 0.01, "float={a} fixed={b}");
    }

    #[test]
    fn fixed_point_quantizes() {
        let f = functions::euclidean2();
        let p = TaylorPoly::expand(&f, &[0.5, 0.5], 3);
        // 2-bit datapath is catastrophically coarse — error must be
        // visibly larger than the 14-bit one.
        let x = [0.3, 0.8];
        let coarse = (p.eval_fixed(&x, 2) - p.eval(&x)).abs();
        let fine = (p.eval_fixed(&x, 14) - p.eval(&x)).abs();
        assert!(coarse > fine);
    }

    #[test]
    fn op_counts_positive_and_scaling() {
        let f = functions::euclidean2();
        let p2 = TaylorPoly::expand(&f, &[0.5, 0.5], 2);
        let p3 = TaylorPoly::expand(&f, &[0.5, 0.5], 3);
        assert!(p3.mul_count() > p2.mul_count());
        assert!(p3.add_count() > 0);
    }

    #[test]
    fn univariate_tanh_expansion() {
        let f = functions::tanh_bipolar(2.0);
        let p = TaylorPoly::expand(&f, &[0.5], 5);
        // Interior accuracy should be decent away from endpoints.
        let v = p.eval(&[0.55]);
        assert!((v - f.eval(&[0.55])).abs() < 1e-3);
    }
}

//! Look-up-table baseline (paper §IV-C, Table VI).
//!
//! Inputs quantized to `addr_bits` each; the table stores the target at
//! every grid point with `out_bits` output resolution. The paper's LUT
//! row (238176.38 µm², 0.10 mW) corresponds to two 8-bit inputs and a
//! 16-bit output word — 2^16 entries × 16 bits. Optional bilinear
//! interpolation shows the classic area↔logic trade-off in the ablation
//! bench.

use crate::synth::functions::TargetFn;

/// A direct-mapped multivariate LUT.
#[derive(Clone, Debug)]
pub struct Lut {
    /// Address bits per input variable.
    pub addr_bits: u32,
    /// Output word width.
    pub out_bits: u32,
    arity: usize,
    /// Quantized outputs, row-major over the address grid.
    table: Vec<u32>,
}

impl Lut {
    /// Tabulate `f` on the `2^addr_bits`-per-dim grid.
    pub fn build(f: &TargetFn, addr_bits: u32, out_bits: u32) -> Self {
        let m = f.arity();
        let side = 1usize << addr_bits;
        let total = side.pow(m as u32);
        assert!(total < (1 << 28), "LUT too large to simulate");
        let out_scale = ((1u64 << out_bits) - 1) as f64;
        let mut table = vec![0u32; total];
        let mut idx = vec![0usize; m];
        let mut x = vec![0.0; m];
        for entry in table.iter_mut() {
            for j in 0..m {
                // Address k represents the cell-centre input value.
                x[j] = (idx[j] as f64 + 0.5) / side as f64;
            }
            let y = f.eval(&x).clamp(0.0, 1.0);
            *entry = (y * out_scale).round() as u32;
            // Odometer.
            let mut j = 0;
            while j < m {
                idx[j] += 1;
                if idx[j] < side {
                    break;
                }
                idx[j] = 0;
                j += 1;
            }
        }
        Self { addr_bits, out_bits, arity: m, table }
    }

    pub fn arity(&self) -> usize {
        self.arity
    }

    /// Number of stored entries.
    pub fn entries(&self) -> usize {
        self.table.len()
    }

    /// Total storage bits — the quantity that dominates Table VI's area.
    pub fn storage_bits(&self) -> u64 {
        self.table.len() as u64 * self.out_bits as u64
    }

    /// Direct lookup.
    pub fn eval(&self, x: &[f64]) -> f64 {
        assert_eq!(x.len(), self.arity);
        let side = 1usize << self.addr_bits;
        let mut addr = 0usize;
        let mut stride = 1usize;
        for &xj in x {
            let k = ((xj.clamp(0.0, 1.0) * side as f64) as usize).min(side - 1);
            addr += k * stride;
            stride *= side;
        }
        let out_scale = ((1u64 << self.out_bits) - 1) as f64;
        self.table[addr] as f64 / out_scale
    }

    /// Mean absolute error on a dense uniform grid.
    pub fn mae_vs(&self, f: &TargetFn, grid: usize) -> f64 {
        let m = self.arity;
        let mut idx = vec![0usize; m];
        let mut x = vec![0.0; m];
        let mut total = 0.0;
        let mut count = 0usize;
        loop {
            for j in 0..m {
                x[j] = idx[j] as f64 / (grid - 1) as f64;
            }
            total += (self.eval(&x) - f.eval(&x)).abs();
            count += 1;
            let mut j = 0;
            loop {
                idx[j] += 1;
                if idx[j] < grid {
                    break;
                }
                idx[j] = 0;
                j += 1;
                if j == m {
                    return total / count as f64;
                }
            }
        }
    }

    /// Smallest per-dimension address width whose direct-mapped LUT
    /// achieves `target_mae` for `f` (the "equalize accuracy, then compare
    /// hardware" methodology of §IV-C).
    pub fn size_for_accuracy(f: &TargetFn, target_mae: f64, out_bits: u32) -> Option<Lut> {
        for addr_bits in 2..=12 {
            if f.arity() as u32 * addr_bits > 26 {
                return None; // beyond simulable size
            }
            let lut = Lut::build(f, addr_bits, out_bits);
            if lut.mae_vs(f, 65) <= target_mae {
                return Some(lut);
            }
        }
        None
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::synth::functions;

    #[test]
    fn shapes() {
        let lut = Lut::build(&functions::euclidean2(), 4, 8);
        assert_eq!(lut.entries(), 256);
        assert_eq!(lut.storage_bits(), 2048);
        assert_eq!(lut.arity(), 2);
    }

    #[test]
    fn lookup_accuracy_scales_with_addr_bits() {
        let f = functions::euclidean2();
        let small = Lut::build(&f, 3, 16).mae_vs(&f, 65);
        let big = Lut::build(&f, 7, 16).mae_vs(&f, 65);
        assert!(big < small, "big={big} small={small}");
        assert!(big < 0.01);
    }

    #[test]
    fn eval_within_quantization_error() {
        let f = functions::product2();
        let lut = Lut::build(&f, 8, 16);
        // At a cell centre, error is just output quantization.
        let x = [(10.0 + 0.5) / 256.0, (20.0 + 0.5) / 256.0];
        assert!((lut.eval(&x) - f.eval(&x)).abs() < 1.0 / 65535.0 + 1e-9);
    }

    #[test]
    fn paper_table6_configuration() {
        // Two 8-bit addresses, 16-bit output: 65536 entries, 1 Mibit.
        let f = functions::euclidean2();
        let lut = Lut::build(&f, 8, 16);
        assert_eq!(lut.entries(), 65536);
        assert_eq!(lut.storage_bits(), 1_048_576);
        // Accuracy far better than the 0.015 equalization point.
        assert!(lut.mae_vs(&f, 65) < 0.005);
    }

    #[test]
    fn size_for_accuracy_monotone() {
        let f = functions::euclidean2();
        let loose = Lut::size_for_accuracy(&f, 0.05, 16).unwrap();
        let tight = Lut::size_for_accuracy(&f, 0.005, 16).unwrap();
        assert!(tight.addr_bits >= loose.addr_bits);
    }

    #[test]
    fn clamps_out_of_domain_inputs() {
        let f = functions::euclidean2();
        let lut = Lut::build(&f, 4, 8);
        let y = lut.eval(&[1.5, -0.5]);
        assert!((0.0..=1.0).contains(&y));
    }
}

//! Bernstein-polynomial stochastic logic (paper ref [12], Qian–Riedel).
//!
//! The classic SC generalization for *univariate* functions: a degree-n
//! Bernstein polynomial `Σ_k b_k B_{k,n}(x)` is computed stochastically by
//! feeding n independent copies of the x bitstream into an adder tree and
//! using the bit-count to select `b_k` from a coefficient MUX — precisely
//! a CPT-gate whose select is a *binomial* state rather than SMURF's
//! Markov state. Included as the second SC baseline and for the ablation
//! bench (Bernstein-vs-SMURF coefficient count at equal accuracy).

use crate::sc::rng::StreamRng;
use crate::sc::sng::ThetaGate;
use crate::synth::functions::TargetFn;
use crate::synth::qp::solve_box_qp;
use crate::synth::quadrature::gauss_legendre_unit;
use crate::util::linalg::Mat;

/// Bernstein basis value `B_{k,n}(x) = C(n,k) x^k (1-x)^{n-k}`.
pub fn bernstein_basis(n: usize, k: usize, x: f64) -> f64 {
    binom(n, k) * x.powi(k as i32) * (1.0 - x).powi((n - k) as i32)
}

fn binom(n: usize, k: usize) -> f64 {
    let k = k.min(n - k.min(n));
    let mut r = 1.0;
    for i in 0..k {
        r *= (n - i) as f64 / (i + 1) as f64;
    }
    r
}

/// A synthesized Bernstein SC generator for a univariate target.
#[derive(Clone, Debug)]
pub struct BernsteinSc {
    /// Degree n (uses n independent input streams).
    pub degree: usize,
    /// Coefficients b_0 … b_n, each in [0,1] (they are MUX θ-gate inputs).
    pub coeffs: Vec<f64>,
}

impl BernsteinSc {
    /// L2-optimal coefficients in the box [0,1]^{n+1} — same QP machinery
    /// as SMURF synthesis, with the Bernstein Gram matrix.
    pub fn synthesize(f: &TargetFn, degree: usize) -> Self {
        assert_eq!(f.arity(), 1, "Bernstein baseline is univariate");
        let n = degree;
        let (xs, ws) = gauss_legendre_unit(64);
        let dim = n + 1;
        let mut h = Mat::zeros(dim, dim);
        let mut c = vec![0.0; dim];
        for (&x, &w) in xs.iter().zip(&ws) {
            let basis: Vec<f64> = (0..dim).map(|k| bernstein_basis(n, k, x)).collect();
            let t = f.eval(&[x]);
            for a in 0..dim {
                c[a] -= w * t * basis[a];
                for b in 0..dim {
                    h.a[a * dim + b] += w * basis[a] * basis[b];
                }
            }
        }
        let (coeffs, _) = solve_box_qp(&h, &c, 50_000, 1e-12);
        Self { degree: n, coeffs }
    }

    /// Analytic (expected) output.
    pub fn eval_analytic(&self, x: f64) -> f64 {
        (0..=self.degree)
            .map(|k| self.coeffs[k] * bernstein_basis(self.degree, k, x))
            .sum()
    }

    /// Bit-level simulation: n independent x-streams, bit-count select,
    /// coefficient θ-gate bank (the ReSC architecture of [12]).
    pub fn eval_bitstream(
        &self,
        x: f64,
        len: usize,
        rngs: &mut [Box<dyn StreamRng>],
        coeff_rng: &mut dyn StreamRng,
    ) -> f64 {
        assert_eq!(rngs.len(), self.degree, "need n independent input streams");
        let gate = ThetaGate::new(x);
        let coeff_gates: Vec<ThetaGate> =
            self.coeffs.iter().map(|&b| ThetaGate::new(b)).collect();
        let mut ones = 0u64;
        for _ in 0..len {
            let k: usize = rngs.iter_mut().map(|r| gate.sample(r.next_u16()) as usize).sum();
            ones += coeff_gates[k].sample(coeff_rng.next_u16()) as u64;
        }
        ones as f64 / len as f64
    }

    /// Grid MAE of the analytic curve.
    pub fn mae_vs(&self, f: &TargetFn, grid: usize) -> f64 {
        let mut total = 0.0;
        for i in 0..grid {
            let x = i as f64 / (grid - 1) as f64;
            total += (self.eval_analytic(x) - f.eval(&[x])).abs();
        }
        total / grid as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sc::rng::XorShift64;
    use crate::synth::functions;

    #[test]
    fn basis_partition_of_unity() {
        for &x in &[0.0, 0.3, 0.7, 1.0] {
            let s: f64 = (0..=5).map(|k| bernstein_basis(5, k, x)).sum();
            assert!((s - 1.0).abs() < 1e-12);
        }
    }

    #[test]
    fn binom_values() {
        assert_eq!(binom(5, 2), 10.0);
        assert_eq!(binom(6, 0), 1.0);
        assert_eq!(binom(6, 6), 1.0);
    }

    #[test]
    fn synthesizes_tanh_accurately() {
        let f = functions::tanh_bipolar(2.0);
        let b = BernsteinSc::synthesize(&f, 6);
        let mae = b.mae_vs(&f, 101);
        assert!(mae < 0.02, "degree-6 Bernstein tanh MAE={mae}");
        // Coefficients must be valid probabilities.
        for &c in &b.coeffs {
            assert!((-1e-9..=1.0 + 1e-9).contains(&c));
        }
    }

    #[test]
    fn higher_degree_is_at_least_as_good() {
        let f = functions::sigmoid_bipolar(4.0);
        let lo = BernsteinSc::synthesize(&f, 3).mae_vs(&f, 101);
        let hi = BernsteinSc::synthesize(&f, 8).mae_vs(&f, 101);
        assert!(hi <= lo + 1e-9, "hi={hi} lo={lo}");
    }

    #[test]
    fn bitstream_converges_to_analytic() {
        let f = functions::tanh_bipolar(2.0);
        let b = BernsteinSc::synthesize(&f, 4);
        let mut rngs: Vec<Box<dyn StreamRng>> = (0..4)
            .map(|i| Box::new(XorShift64::new(1000 + i)) as Box<dyn StreamRng>)
            .collect();
        let mut crng = XorShift64::new(2000);
        let x = 0.6;
        let y = b.eval_bitstream(x, 100_000, &mut rngs, &mut crng);
        assert!((y - b.eval_analytic(x)).abs() < 0.01, "y={y}");
    }
}

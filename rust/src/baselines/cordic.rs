//! CORDIC baseline (paper §III-C Discussion, Table III).
//!
//! Fixed-point CORDIC engines for the univariate primitives the paper's
//! Table III decomposes multivariate functions into:
//! circular-rotation (sin/cos), circular-vectoring (√(x²+y²) — note the
//! paper charges CORDIC 2 squarings + 1 sqrt for the Euclidean distance,
//! we additionally provide the vectoring shortcut), hyperbolic-rotation
//! (sinh/cosh → exp), and linear-vectoring (divide).
//!
//! Also here: the symbolic operation-count comparison that regenerates
//! Table III programmatically from expression decompositions.

/// Number of CORDIC iterations (bits of precision).
pub const DEFAULT_ITERS: usize = 16;

/// Circular-rotation CORDIC: returns (cos θ, sin θ) for θ in radians,
/// |θ| ≤ ~1.74 (the CORDIC convergence range).
pub fn sin_cos(theta: f64, iters: usize) -> (f64, f64) {
    let mut x = 1.0;
    let mut y = 0.0;
    let mut z = theta;
    for i in 0..iters {
        let d = if z >= 0.0 { 1.0 } else { -1.0 };
        let shift = 2f64.powi(-(i as i32));
        let (xn, yn) = (x - d * y * shift, y + d * x * shift);
        z -= d * (2f64.powi(-(i as i32))).atan();
        x = xn;
        y = yn;
    }
    let k = gain(iters);
    (x / k, y / k)
}

/// Circular-vectoring CORDIC: returns (√(x²+y²), atan2(y,x)) for x > 0.
pub fn vectoring(x0: f64, y0: f64, iters: usize) -> (f64, f64) {
    let mut x = x0;
    let mut y = y0;
    let mut z = 0.0;
    for i in 0..iters {
        let d = if y >= 0.0 { -1.0 } else { 1.0 };
        let shift = 2f64.powi(-(i as i32));
        let (xn, yn) = (x - d * y * shift, y + d * x * shift);
        z -= d * (2f64.powi(-(i as i32))).atan();
        x = xn;
        y = yn;
    }
    (x / gain(iters), z)
}

/// Hyperbolic-rotation CORDIC: returns (cosh θ, sinh θ), |θ| ≤ ~1.13.
/// Iterations 4 and 13 are repeated per the classic convergence fix.
pub fn cosh_sinh(theta: f64, iters: usize) -> (f64, f64) {
    let mut x = 1.0;
    let mut y = 0.0;
    let mut z = theta;
    let mut k = 1.0;
    let mut i = 1; // hyperbolic mode starts at i=1
    let mut repeated4 = false;
    let mut repeated13 = false;
    let mut count = 0;
    while count < iters {
        let d = if z >= 0.0 { 1.0 } else { -1.0 };
        let shift = 2f64.powi(-(i as i32));
        let (xn, yn) = (x + d * y * shift, y + d * x * shift);
        z -= d * shift.atanh();
        x = xn;
        y = yn;
        k *= (1.0 - shift * shift).sqrt();
        count += 1;
        // Repeat i = 4 and i = 13 once.
        if i == 4 && !repeated4 {
            repeated4 = true;
        } else if i == 13 && !repeated13 {
            repeated13 = true;
        } else {
            i += 1;
        }
    }
    // The iteration scales the invariant x²−y² by k² = Π(1−2^{-2i}),
    // so the true (cosh, sinh) are recovered by dividing by k.
    (x / k, y / k)
}

/// exp(θ) = cosh θ + sinh θ for |θ| ≤ 1.13; extended by argument
/// reduction exp(θ) = 2^m · exp(r).
pub fn exp(theta: f64, iters: usize) -> f64 {
    // Reduce into convergence range using ln 2 steps.
    let m = (theta / std::f64::consts::LN_2).round();
    let r = theta - m * std::f64::consts::LN_2;
    let (c, s) = cosh_sinh(r, iters);
    (c + s) * 2f64.powi(m as i32)
}

/// Linear-vectoring CORDIC division y/x for |y| < 2|x|.
pub fn divide(y: f64, x: f64, iters: usize) -> f64 {
    let mut yv = y;
    let mut z = 0.0;
    let mut t = 1.0;
    for _ in 0..iters {
        let d = if (yv >= 0.0) == (x >= 0.0) { 1.0 } else { -1.0 };
        yv -= d * x * t;
        z += d * t;
        t *= 0.5;
    }
    z
}

/// sqrt via hyperbolic vectoring: √v = √((v+¼)² − (v−¼)²) — the standard
/// CORDIC square-root trick.
pub fn sqrt(v: f64, iters: usize) -> f64 {
    if v <= 0.0 {
        return 0.0;
    }
    // Normalize v into [0.25, 1) by even exponent shifts.
    let mut m = 0i32;
    let mut u = v;
    while u >= 1.0 {
        u /= 4.0;
        m += 1;
    }
    while u < 0.25 {
        u *= 4.0;
        m -= 1;
    }
    let mut x = u + 0.25;
    let mut y = u - 0.25;
    let mut k = 1.0;
    let mut i = 1;
    let mut repeated4 = false;
    let mut repeated13 = false;
    let mut count = 0;
    while count < iters {
        let shift = 2f64.powi(-(i as i32));
        let d = if y >= 0.0 { -1.0 } else { 1.0 };
        let (xn, yn) = (x + d * y * shift, y + d * x * shift);
        x = xn;
        y = yn;
        k *= (1.0 - shift * shift).sqrt();
        count += 1;
        if i == 4 && !repeated4 {
            repeated4 = true;
        } else if i == 13 && !repeated13 {
            repeated13 = true;
        } else {
            i += 1;
        }
    }
    (x / k) * 2f64.powi(m)
}

fn gain(iters: usize) -> f64 {
    (0..iters).map(|i| (1.0 + 2f64.powi(-2 * (i as i32))).sqrt()).product()
}

// ---------------------------------------------------------------------------
// Table III: symbolic operation counts
// ---------------------------------------------------------------------------

/// One row of the Table III comparison.
#[derive(Clone, Debug, PartialEq)]
pub struct OpCount {
    pub scheme: &'static str,
    pub function: &'static str,
    /// (operation name, count)
    pub ops: Vec<(&'static str, usize)>,
}

impl OpCount {
    /// Total number of distinct hardware evaluation units.
    pub fn total_units(&self) -> usize {
        self.ops.iter().map(|(_, c)| c).sum()
    }
}

/// The CORDIC decompositions the paper's Table III lists.
pub fn table3_cordic() -> Vec<OpCount> {
    vec![
        OpCount {
            scheme: "CORDIC",
            function: "sqrt(x1^2+x2^2)",
            ops: vec![("square", 2), ("sqrt", 1)],
        },
        OpCount {
            scheme: "CORDIC",
            function: "sin(x1)cos(x2)",
            ops: vec![("sin", 2), ("cos", 1), ("add", 1), ("multiply", 1)],
        },
        OpCount {
            scheme: "CORDIC",
            function: "exp(x1)/(exp(x1)+exp(x2))",
            ops: vec![("exp", 2), ("add", 1), ("divide", 1)],
        },
    ]
}

/// SMURF needs exactly one generator per function (Table III bottom row).
pub fn table3_smurf() -> Vec<OpCount> {
    vec![
        OpCount { scheme: "SMURF", function: "sqrt(x1^2+x2^2)", ops: vec![("SMURF", 1)] },
        OpCount { scheme: "SMURF", function: "sin(x1)cos(x2)", ops: vec![("SMURF", 1)] },
        OpCount {
            scheme: "SMURF",
            function: "exp(x1)/(exp(x1)+exp(x2))",
            ops: vec![("SMURF", 1)],
        },
    ]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sin_cos_accuracy() {
        for &t in &[0.0, 0.3, 0.7, 1.0, -0.5] {
            let (c, s) = sin_cos(t, 24);
            assert!((c - t.cos()).abs() < 1e-5, "cos({t})={c}");
            assert!((s - t.sin()).abs() < 1e-5, "sin({t})={s}");
        }
    }

    #[test]
    fn vectoring_magnitude() {
        let (r, a) = vectoring(0.3, 0.4, 24);
        assert!((r - 0.5).abs() < 1e-5, "r={r}");
        assert!((a - (0.4f64 / 0.3).atan()).abs() < 1e-5, "a={a}");
    }

    #[test]
    fn exp_accuracy() {
        for &t in &[0.0, 0.5, 1.0, -0.7, 2.3] {
            let e = exp(t, 24);
            assert!((e - t.exp()).abs() / t.exp() < 1e-5, "exp({t})={e}");
        }
    }

    #[test]
    fn divide_accuracy() {
        assert!((divide(0.3, 0.8, 30) - 0.375).abs() < 1e-6);
        assert!((divide(-0.5, 0.9, 30) + 0.5555555).abs() < 1e-4);
    }

    #[test]
    fn sqrt_accuracy() {
        for &v in &[0.04, 0.25, 0.5, 0.9, 2.0, 16.0] {
            let s = sqrt(v, 30);
            assert!((s - v.sqrt()).abs() < 1e-4, "sqrt({v})={s} vs {}", v.sqrt());
        }
    }

    #[test]
    fn sqrt_edge_cases() {
        assert_eq!(sqrt(0.0, 16), 0.0);
        assert_eq!(sqrt(-1.0, 16), 0.0);
    }

    #[test]
    fn table3_shape_matches_paper() {
        let cordic = table3_cordic();
        let smurf = table3_smurf();
        assert_eq!(cordic.len(), 3);
        assert_eq!(smurf.len(), 3);
        // Paper's claim: SMURF uses 1 unit everywhere; CORDIC at least 3.
        for row in &smurf {
            assert_eq!(row.total_units(), 1);
        }
        for row in &cordic {
            assert!(row.total_units() >= 3, "{row:?}");
        }
    }

    #[test]
    fn euclid_via_cordic_pipeline() {
        // The paper's decomposition: 2 squarings (via multiply) + 1 sqrt.
        let (x1, x2): (f64, f64) = (0.6, 0.3);
        let sq = x1 * x1 + x2 * x2;
        let r = sqrt(sq, 30);
        assert!((r - (x1 * x1 + x2 * x2).sqrt()).abs() < 1e-4);
        // And the vectoring shortcut agrees.
        let (rv, _) = vectoring(x1, x2, 30);
        assert!((rv - r).abs() < 1e-4);
    }
}

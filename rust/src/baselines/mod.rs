//! Comparator schemes the paper evaluates against (§I, §IV):
//!
//! - [`taylor`] — fixed-point Taylor-series polynomial evaluation (the
//!   paper's main hardware comparison, Table VI).
//! - [`lut`] — quantized look-up tables (Table VI).
//! - [`cordic`] — CORDIC iterations for the univariate primitives, used to
//!   reproduce the operation-count comparison of Table III.
//! - [`bernstein`] — Qian–Riedel Bernstein-polynomial stochastic logic
//!   (ref [12]), the other classic SC generalization.

pub mod bernstein;
pub mod cordic;
pub mod lut;
pub mod taylor;

//! Minimal property-testing harness.
//!
//! `proptest` is not vendored in this offline environment, so this module
//! provides the slice of it the test suite needs: seeded random input
//! generation, a fixed number of cases, and greedy shrinking of numeric
//! inputs toward simple values on failure. Failures report the seed and
//! the (shrunk) counterexample.

use crate::util::prng::Pcg;

/// Number of cases each property runs by default.
pub const DEFAULT_CASES: usize = 256;

/// A generator of random test values.
pub trait Gen<T> {
    fn generate(&self, rng: &mut Pcg) -> T;
    /// Candidate simplifications of a failing value (tried in order).
    fn shrink(&self, value: &T) -> Vec<T> {
        let _ = value;
        Vec::new()
    }
}

/// Uniform `f64` in `[lo, hi]`.
pub struct UnitF64 {
    pub lo: f64,
    pub hi: f64,
}

impl UnitF64 {
    pub fn unit() -> Self {
        Self { lo: 0.0, hi: 1.0 }
    }
}

impl Gen<f64> for UnitF64 {
    fn generate(&self, rng: &mut Pcg) -> f64 {
        rng.range(self.lo, self.hi)
    }

    fn shrink(&self, value: &f64) -> Vec<f64> {
        let mut cands = Vec::new();
        for c in [self.lo, self.hi, 0.5 * (self.lo + self.hi)] {
            if c != *value {
                cands.push(c);
            }
        }
        // Halve the distance to the midpoint.
        let mid = 0.5 * (self.lo + self.hi);
        let half = mid + (value - mid) * 0.5;
        if (half - value).abs() > 1e-12 {
            cands.push(half);
        }
        cands
    }
}

/// Uniform `usize` in `[lo, hi]` inclusive.
pub struct RangeUsize {
    pub lo: usize,
    pub hi: usize,
}

impl Gen<usize> for RangeUsize {
    fn generate(&self, rng: &mut Pcg) -> usize {
        self.lo + rng.below((self.hi - self.lo + 1) as u64) as usize
    }

    fn shrink(&self, value: &usize) -> Vec<usize> {
        let mut cands = Vec::new();
        if *value > self.lo {
            cands.push(self.lo);
            cands.push(self.lo + (value - self.lo) / 2);
        }
        cands.retain(|c| c != value);
        cands.dedup();
        cands
    }
}

/// Fixed-length vector of unit-interval f64s.
pub struct UnitVec {
    pub len: usize,
}

impl Gen<Vec<f64>> for UnitVec {
    fn generate(&self, rng: &mut Pcg) -> Vec<f64> {
        (0..self.len).map(|_| rng.uniform()).collect()
    }

    fn shrink(&self, value: &Vec<f64>) -> Vec<Vec<f64>> {
        let mut cands = Vec::new();
        // All-zeros, all-halves and element-wise midpoint pulls.
        if value.iter().any(|&x| x != 0.0) {
            cands.push(vec![0.0; self.len]);
        }
        if value.iter().any(|&x| x != 0.5) {
            cands.push(vec![0.5; self.len]);
        }
        for i in 0..self.len {
            if value[i] != 0.5 {
                let mut v = value.clone();
                v[i] = 0.5;
                cands.push(v);
            }
        }
        cands
    }
}

/// Run `prop` on `cases` random inputs from `gen`; on failure, shrink and
/// panic with the minimal counterexample found.
pub fn check<T: std::fmt::Debug + Clone>(
    seed: u64,
    cases: usize,
    gen: &impl Gen<T>,
    prop: impl Fn(&T) -> bool,
) {
    let mut rng = Pcg::new(seed);
    for case in 0..cases {
        let input = gen.generate(&mut rng);
        if prop(&input) {
            continue;
        }
        // Shrink: greedy first-improvement passes, bounded.
        let mut cur = input.clone();
        'outer: for _ in 0..64 {
            for cand in gen.shrink(&cur) {
                if !prop(&cand) {
                    cur = cand;
                    continue 'outer;
                }
            }
            break;
        }
        panic!(
            "property failed (seed={seed}, case={case})\n  original: {input:?}\n  shrunk:   {cur:?}"
        );
    }
}

/// Convenience wrapper with [`DEFAULT_CASES`].
pub fn check_default<T: std::fmt::Debug + Clone>(
    seed: u64,
    gen: &impl Gen<T>,
    prop: impl Fn(&T) -> bool,
) {
    check(seed, DEFAULT_CASES, gen, prop)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        check_default(1, &UnitF64::unit(), |&x| (0.0..=1.0).contains(&x));
    }

    #[test]
    #[should_panic(expected = "property failed")]
    fn failing_property_reports() {
        check(2, 64, &UnitF64::unit(), |&x| x < 0.9);
    }

    #[test]
    fn shrinking_reaches_simple_value() {
        // Capture the panic message and confirm the shrunk value is still
        // a counterexample (greedy first-improvement shrinking lands on
        // the simplest failing candidate — here the upper endpoint).
        let result = std::panic::catch_unwind(|| {
            check(3, 128, &UnitF64::unit(), |&x| x < 0.9);
        });
        let msg = *result.unwrap_err().downcast::<String>().unwrap();
        let shrunk: f64 = msg
            .lines()
            .find(|l| l.contains("shrunk"))
            .and_then(|l| l.split_whitespace().last())
            .unwrap()
            .parse()
            .unwrap();
        assert!((0.9..=1.0).contains(&shrunk), "shrunk={shrunk} not a counterexample");
    }

    #[test]
    fn unit_vec_shapes() {
        let mut rng = Pcg::new(4);
        let v = UnitVec { len: 5 }.generate(&mut rng);
        assert_eq!(v.len(), 5);
        assert!(v.iter().all(|&x| (0.0..1.0).contains(&x)));
    }

    #[test]
    fn range_usize_inclusive() {
        let mut rng = Pcg::new(5);
        let g = RangeUsize { lo: 3, hi: 8 };
        for _ in 0..100 {
            let v = g.generate(&mut rng);
            assert!((3..=8).contains(&v));
        }
    }
}

//! Randomized robustness harness: seeded structured fuzzing, the
//! differential oracle, and the coordinator chaos soak.
//!
//! The paper's claim — bit-level stochasticity traded for hardware
//! simplicity *without* losing accuracy — only holds if every
//! implementation layer agrees exactly where it must and within bound
//! where it may. The hand-written suites pin known scenarios; this
//! module hunts the rest of the input space automatically, with zero
//! external dependencies and total seed determinism (every failure is a
//! one-line repro).
//!
//! Three layers:
//!
//! - [`arbitrary`] — structured generators: one [`crate::util::prng::Pcg`]
//!   seed expands into a complete, valid-and-hostile [`arbitrary::FuzzCase`]
//!   (mixed radices, θ tables including boundary rows 0/65535, domain-edge
//!   and subnormal inputs, lane-boundary stream lengths, entropy modes,
//!   fault plans).
//! - [`oracle`] — the differential oracle: the exact-equality lattice
//!   (scalar simulator == every compiled plane width == TMR at rate 0 ==
//!   armed-zero fault hooks, bit for bit), the bounded analytic relation,
//!   and a shrinker that minimizes a failing case (num_vars → radices →
//!   L → table rows) and renders the minimized seed + config before the
//!   caller fails.
//! - [`soak`] — the chaos-soak round engine shared by
//!   `rust/tests/soak.rs` and `examples/soak.rs`: each round builds an
//!   `EvalServer` + `ResilientClient` from a round seed, drives a mixed
//!   workload under a randomized fault schedule, then audits the global
//!   invariants (answered-exactly-once, depth drained, pool respawned,
//!   metrics conservation, sentinel/breaker legality, byte-identical
//!   replay).
//!
//! Entry points: `make fuzz-smoke` (oracle over N seeded cases, tier-1
//! time) and `make soak SOAK_ROUNDS=… SOAK_SEED=…`. Documented in
//! `docs/INVARIANTS.md` § Randomized robustness harness.
//!
//! This module is production-compiled (the integration tests and the
//! example driver consume it from outside the crate), so it lives under
//! the same `no-panic` xtask rule as the coordinator: failures are
//! `Result<_, String>` values carrying the case description, never
//! panics — the *callers* (tests, drivers) decide how to fail.

pub mod arbitrary;
pub mod oracle;
pub mod soak;

pub use arbitrary::FuzzCase;
pub use oracle::{check_case, run_seeded, shrink_case, CheckFailure};
pub use soak::{run_round, run_soak, RoundReport, SoakOptions};

//! Chaos-soak round engine: randomized serving-stack configurations,
//! randomized fault schedules, mixed workloads, and global invariant
//! audits — all derived from one [`Pcg`] seed per round.
//!
//! Each round ([`run_round`]) builds a fresh [`EvalServer`] +
//! [`ResilientClient`] whose every knob (worker count, batch policy,
//! admission limits, retry/budget/hedge/breaker rungs, fault mode) is
//! drawn from the round seed, fires a mixed workload from concurrent
//! client threads, and then audits the invariants the serving core
//! promises regardless of configuration:
//!
//! - **answered exactly once** — the metrics conservation ledger
//!   ([`crate::coordinator::metrics::Snapshot::check_conservation`])
//!   balances after the queues drain;
//! - **depth drained** — admission depth counters return to 0;
//! - **pool respawned** — the supervisor returns the worker pool to its
//!   configured size after injected panics;
//! - **payload fidelity** — every successful response equals its
//!   deterministic reference bit-for-bit (analytic closed form for
//!   `Analytic`/degraded traffic; the seeded bitstream contract
//!   `eval_bitstream(p, L, DEFAULT_STREAM_SEED ^ i)` — plus the armed
//!   bias, when drifting — for `BitLevel`), and well-formed calls are
//!   never answered `BadRequest`;
//! - **sentinel/breaker legality** — quarantine-degraded traffic implies
//!   a recorded drift alarm; breaker fast-fails imply a recorded open;
//!   hedge losers never diverge from winners;
//! - **byte-identical replay** — re-running the same round seed against
//!   a fresh server produces bitwise-equal successful payloads
//!   (compared index-aligned, on calls that succeeded in both runs with
//!   the same degradation state).
//!
//! The engine is shared by the `#[ignore]`d integration test
//! (`rust/tests/soak.rs`, via `make soak SOAK_ROUNDS=… SOAK_SEED=…`)
//! and the standalone driver (`examples/soak.rs`). Like the rest of
//! `testutil`, nothing here panics in non-test code: every violation is
//! an `Err(String)` naming the round seed — a one-line repro.

use crate::coordinator::batcher::BatchPolicy;
use crate::coordinator::{
    AdmissionConfig, BreakerConfig, BudgetConfig, ClientConfig, Engine, EvalError, EvalServer,
    FaultInjector, FlakyWindow, HedgeConfig, HedgeDelay, RejectReason, ResilientClient,
    RetryPolicy, SentinelConfig, ServerConfig, DEFAULT_STREAM_SEED,
};
use crate::smurf::approximator::SmurfApproximator;
use crate::smurf::config::SmurfConfig;
use crate::synth::functions;
use crate::util::prng::{Pcg, GOLDEN_GAMMA};
use crate::util::sync::Arc;
use std::collections::HashMap;
use std::time::{Duration, Instant};

/// Options for a soak run ([`run_soak`]). The defaults match the CI
/// smoke configuration; `make soak` overrides rounds/seed from the
/// environment.
#[derive(Clone, Copy, Debug)]
pub struct SoakOptions {
    /// Base seed; round `r` derives its seed as
    /// `seed.wrapping_add(r · GOLDEN_GAMMA)`.
    pub seed: u64,
    /// Number of independent rounds.
    pub rounds: usize,
    /// Concurrent client threads per round.
    pub clients: usize,
    /// Calls issued by each client thread.
    pub requests_per_client: usize,
    /// Re-run every round against a fresh server from the identical
    /// seed and require byte-identical successful payloads.
    pub replay: bool,
}

impl Default for SoakOptions {
    fn default() -> Self {
        Self { seed: 0xC4A05, rounds: 8, clients: 3, requests_per_client: 24, replay: true }
    }
}

/// The fault schedule a round arms before its workload starts.
#[derive(Clone, Copy, Debug, PartialEq)]
enum FaultMode {
    /// Inert injector: the control round.
    None,
    /// One-shot worker panic on a near-future batch.
    PanicOnce,
    /// One-shot stall on a near-future batch.
    StallOnce,
    /// Bounded Bernoulli window of intermittent panics + stalls.
    Flaky,
    /// Every BitLevel output replaced with NaN (non-finite guard path).
    PoisonNan,
    /// Constant bias on BitLevel outputs (drift-sentinel path); the
    /// payload carried alongside is the bias magnitude.
    Bias,
}

/// Everything a round derives from its seed before any thread starts.
#[derive(Clone, Debug)]
struct RoundPlan {
    workers: usize,
    policy: BatchPolicy,
    admission: AdmissionConfig,
    sentinel_enabled: bool,
    client_cfg: ClientConfig,
    fault: FaultMode,
    /// Bias magnitude for [`FaultMode::Bias`] (0.0 otherwise).
    bias: f64,
    /// Flaky-window parameters for [`FaultMode::Flaky`].
    flaky: FlakyWindow,
    /// One-shot batch ordinal for PanicOnce / StallOnce.
    one_shot_batch: u64,
    stall: Duration,
    /// Per-call client deadline.
    call_timeout: Duration,
}

/// What one client call looked like, recorded for the replay audit.
#[derive(Clone, Debug)]
pub struct CallRecord {
    /// Engine actually requested (after the workload draw).
    pub engine: Engine,
    /// `degraded` flag on the response (shed or quarantined).
    pub degraded: bool,
    /// `None` on success; the error's summary kind otherwise.
    pub error: Option<String>,
    /// Successful payload (empty on error).
    pub outputs: Vec<f64>,
}

/// Per-round audit summary returned by [`run_round`].
#[derive(Clone, Debug, Default)]
pub struct RoundReport {
    /// The round's seed (one-line repro: rerun with this seed).
    pub seed: u64,
    /// Human-readable description of the drawn configuration.
    pub plan: String,
    /// Calls issued across all client threads (primary run).
    pub calls: usize,
    /// Successful responses.
    pub ok: usize,
    /// Successful responses served degraded (shed or quarantined).
    pub degraded_ok: usize,
    /// Typed errors by kind.
    pub errors: Vec<(String, usize)>,
    /// Replay pairs compared bitwise (0 when replay was disabled).
    pub replay_compared: usize,
    /// Worker panics recorded by the server.
    pub panics: u64,
    /// Threads respawned by supervision.
    pub respawns: u64,
    /// Drift alarms recorded by the sentinel.
    pub drift_alarms: u64,
    /// Breaker opens recorded by the client.
    pub breaker_opens: u64,
}

impl RoundReport {
    /// One-line summary for drivers.
    pub fn render(&self) -> String {
        let errs: Vec<String> =
            self.errors.iter().map(|(k, n)| format!("{k}×{n}")).collect();
        format!(
            "round seed={:#x} calls={} ok={} (degraded {}) errors=[{}] replay_compared={} \
             panics={} respawns={} drift_alarms={} breaker_opens={} :: {}",
            self.seed,
            self.calls,
            self.ok,
            self.degraded_ok,
            errs.join(", "),
            self.replay_compared,
            self.panics,
            self.respawns,
            self.drift_alarms,
            self.breaker_opens,
            self.plan,
        )
    }
}

/// The function zoo every round serves: arity-2 targets on the uniform
/// (2 vars × radix 4) lattice, synthesized at a 64-cycle default length.
const FUNCTION_NAMES: [&str; 3] = ["euclidean2", "product2", "softmax2"];

fn build_functions() -> Result<Vec<SmurfApproximator>, String> {
    let cfg = SmurfConfig::uniform(2, 4);
    let mut out = Vec::new();
    for name in FUNCTION_NAMES {
        let target = functions::by_name(name)
            .ok_or_else(|| format!("soak function zoo references unknown target {name:?}"))?;
        out.push(SmurfApproximator::synthesize(&cfg, &target, 64));
    }
    Ok(out)
}

/// Expand a round seed into the full configuration draw. Every field is
/// a pure function of the seed, so an identical-seed replay rebuilds an
/// identical stack.
fn draw_plan(seed: u64) -> RoundPlan {
    let mut rng = Pcg::new(seed);
    let workers = 2 + rng.below(3) as usize; // 2..=4
    let policy = BatchPolicy {
        max_batch: 2 + rng.below(15) as usize, // 2..=16
        max_wait: Duration::from_micros(200 + rng.below(1800)), // 200µs..2ms
    };
    let bitlevel_limit = 8 + rng.below(57) as usize; // 8..=64
    let shed_high = (bitlevel_limit / 2).max(2);
    let admission = AdmissionConfig {
        bitlevel_limit,
        analytic_limit: 256,
        xla_limit: 64,
        shed_high,
        shed_low: (shed_high / 2).max(1),
        sync_timeout: Duration::from_secs(5),
    };
    let sentinel_enabled = rng.below(4) != 0; // armed 3/4 of rounds

    let retry = (rng.below(2) == 0).then(|| {
        let base = Duration::from_millis(1 + rng.below(4));
        RetryPolicy {
            max_retries: 1 + rng.below(3) as u32,
            attempt_timeout: Some(Duration::from_millis(20 + rng.below(41))),
            backoff_base: base,
            backoff_max: base * (2 + rng.below(7) as u32),
            jitter_seed: rng.next_u64(),
        }
    });
    let budget = (rng.below(3) == 0).then(|| {
        let initial = 2.0 + rng.below(9) as f64;
        BudgetConfig { initial, max: initial + rng.below(9) as f64, earn_per_success: rng.range(0.1, 1.0) }
    });
    let hedge = (rng.below(4) == 0).then(|| HedgeConfig {
        delay: HedgeDelay::Fixed(Duration::from_millis(5 + rng.below(16))),
    });
    let breaker = (rng.below(4) == 0).then(|| BreakerConfig {
        failure_threshold: 2 + rng.below(5) as u32,
        probe_interval: 2 + rng.below(3) as u32,
        probe_successes: 1 + rng.below(3) as u32,
    });
    let call_timeout = Duration::from_millis(250 + rng.below(751)); // 250ms..1s
    let client_cfg = ClientConfig {
        total_timeout: Some(call_timeout),
        retry,
        budget,
        hedge,
        breaker,
    };

    let fault = match rng.below(6) {
        0 => FaultMode::None,
        1 => FaultMode::PanicOnce,
        2 => FaultMode::StallOnce,
        3 => FaultMode::Flaky,
        4 => FaultMode::PoisonNan,
        _ => FaultMode::Bias,
    };
    // Bias palette straddles the default quarantine threshold (0.15):
    // 0.25 drives real quarantines, the smaller magnitudes exercise the
    // canary EWMA without tripping it.
    let bias = match rng.below(3) {
        0 => 0.25,
        1 => 0.125,
        _ => 0.0625,
    };
    let flaky = FlakyWindow {
        seed: rng.next_u64(),
        panic_prob: rng.range(0.05, 0.3),
        stall_prob: rng.range(0.05, 0.3),
        stall: Duration::from_millis(2 + rng.below(14)),
        batches: 8 + rng.below(25),
    };
    let one_shot_batch = 1 + rng.below(4);
    let stall = Duration::from_millis(10 + rng.below(31));
    RoundPlan {
        workers,
        policy,
        admission,
        sentinel_enabled,
        client_cfg,
        fault,
        bias,
        flaky,
        one_shot_batch,
        stall,
        call_timeout,
    }
}

fn describe_plan(plan: &RoundPlan) -> String {
    let rungs = format!(
        "retry={} budget={} hedge={} breaker={}",
        plan.client_cfg.retry.is_some(),
        plan.client_cfg.budget.is_some(),
        plan.client_cfg.hedge.is_some(),
        plan.client_cfg.breaker.is_some(),
    );
    format!(
        "workers={} max_batch={} bitlevel_limit={} shed_high={} sentinel={} fault={:?} bias={} {}",
        plan.workers,
        plan.policy.max_batch,
        plan.admission.bitlevel_limit,
        plan.admission.shed_high,
        plan.sentinel_enabled,
        plan.fault,
        plan.bias,
        rungs,
    )
}

/// Arm the round's fault schedule on a fresh injector.
fn arm_faults(plan: &RoundPlan, faults: &FaultInjector) {
    match plan.fault {
        FaultMode::None => {}
        FaultMode::PanicOnce => faults.arm_panic_on_batch(plan.one_shot_batch),
        FaultMode::StallOnce => faults.arm_stall_on_batch(plan.one_shot_batch, plan.stall),
        FaultMode::Flaky => faults.arm_flaky_window(plan.flaky),
        FaultMode::PoisonNan => faults.set_poison_nan(true),
        FaultMode::Bias => faults.set_output_bias(plan.bias),
    }
}

/// Disarm the steady-state faults so the drain window runs clean (the
/// one-shot triggers clear themselves on firing; an unfired one-shot is
/// harmless after the workload stops submitting).
fn clear_faults(faults: &FaultInjector) {
    faults.set_poison_nan(false);
    faults.set_output_bias(0.0);
    faults.clear_flaky_window();
}

/// Hostile-but-valid coordinate palette (the domain for the round's
/// function zoo is the unit square): exact endpoints, subnormals,
/// quantization-grid points, and plain uniform draws.
fn gen_coord(rng: &mut Pcg) -> f64 {
    match rng.below(8) {
        0 => 0.0,
        1 => 1.0,
        2 => -0.0,
        3 => 5e-324,                      // smallest positive subnormal
        4 => 1.0 - f64::EPSILON,
        5 => rng.below(65537) as f64 / 65536.0, // θ-quantization grid
        _ => rng.uniform(),
    }
}

/// One drawn call: what to send and what the contract allows back.
struct CallSpec {
    function: &'static str,
    points: Vec<Vec<f64>>,
    engine: Engine,
    stream_len: usize,
    /// True when the call is deliberately malformed and must be refused.
    bad: bool,
}

fn draw_call(rng: &mut Pcg) -> CallSpec {
    let function = FUNCTION_NAMES[rng.below(FUNCTION_NAMES.len() as u64) as usize];
    let engine = match rng.below(10) {
        0 => Engine::Xla,
        1..=4 => Engine::Analytic,
        _ => Engine::BitLevel,
    };
    let stream_len = [1usize, 63, 64, 65, 128, 256][rng.below(6) as usize];
    let n_points = 1 + rng.below(3) as usize;
    let mut points: Vec<Vec<f64>> =
        (0..n_points).map(|_| vec![gen_coord(rng), gen_coord(rng)]).collect();
    // ~1/8 of traffic is deliberately malformed; the kinds used here are
    // refused by validation regardless of engine rewrites (arity, NaN
    // input, unknown function), so the expectation is route-independent.
    let bad = rng.below(8) == 0;
    let mut spec = CallSpec { function, points: Vec::new(), engine, stream_len, bad };
    if bad {
        match rng.below(3) {
            0 => points[0] = vec![0.5], // arity mismatch
            1 => points[0] = vec![f64::NAN, 0.5],
            _ => spec.function = "no_such_function",
        }
    }
    spec.points = points;
    spec
}

/// Summarize a typed error for the per-kind tally (payloads vary; the
/// kind is what the invariants speak about).
fn error_kind(e: &EvalError) -> &'static str {
    match e {
        EvalError::Rejected(RejectReason::QueueFull) => "rejected:queue_full",
        EvalError::Rejected(RejectReason::BadRequest(_)) => "rejected:bad_request",
        EvalError::Rejected(RejectReason::Deadline) => "rejected:deadline",
        EvalError::Timeout => "timeout",
        EvalError::WorkerPanic(_) => "worker_panic",
        EvalError::Shutdown => "shutdown",
        EvalError::Engine(_) => "engine",
        EvalError::CircuitOpen => "circuit_open",
    }
}

/// Check one successful payload against its deterministic reference.
/// `refs` maps function name → synthesized reference approximator.
fn check_payload(
    refs: &HashMap<&'static str, SmurfApproximator>,
    plan: &RoundPlan,
    spec: &CallSpec,
    degraded: bool,
    outputs: &[f64],
) -> Result<(), String> {
    let func = refs
        .get(spec.function)
        .ok_or_else(|| format!("no reference for function {:?}", spec.function))?;
    if outputs.len() != spec.points.len() {
        return Err(format!(
            "payload arity: {} outputs for {} points",
            outputs.len(),
            spec.points.len()
        ));
    }
    for (i, (y, p)) in outputs.iter().zip(&spec.points).enumerate() {
        if !y.is_finite() {
            return Err(format!("non-finite output {y} escaped the worker guard (point {i})"));
        }
        let engine_is_analytic = spec.engine == Engine::Analytic || degraded;
        let want = if engine_is_analytic {
            func.eval_analytic(p)
        } else {
            // Non-degraded BitLevel: the seeded bitstream contract, plus
            // the armed bias (applied by the injector as one IEEE add).
            let raw = func.eval_bitstream(p, spec.stream_len, DEFAULT_STREAM_SEED ^ i as u64);
            if plan.fault == FaultMode::Bias {
                raw + plan.bias
            } else {
                raw
            }
        };
        if plan.fault == FaultMode::PoisonNan && !engine_is_analytic {
            return Err(format!(
                "BitLevel call succeeded un-degraded while NaN poisoning was armed \
                 (point {i}, output {y})"
            ));
        }
        if y.to_bits() != want.to_bits() {
            return Err(format!(
                "payload mismatch at point {i}: got {y:?} ({:#x}), want {want:?} ({:#x}) \
                 [engine={:?} degraded={degraded} L={}]",
                y.to_bits(),
                want.to_bits(),
                spec.engine,
                spec.stream_len
            ));
        }
    }
    Ok(())
}

/// Stats one client thread accumulates.
#[derive(Default)]
struct ClientStats {
    ok: usize,
    degraded_ok: usize,
    errors: Vec<(String, usize)>,
}

impl ClientStats {
    fn count_error(&mut self, kind: &str) {
        if let Some(slot) = self.errors.iter_mut().find(|(k, _)| k == kind) {
            slot.1 += 1;
        } else {
            self.errors.push((kind.to_string(), 1));
        }
    }
}

/// Drive one client thread's workload; returns its call records and
/// stats, or the first invariant violation.
fn run_client(
    server: &EvalServer,
    refs: &HashMap<&'static str, SmurfApproximator>,
    plan: &RoundPlan,
    seed: u64,
    calls: usize,
) -> Result<(Vec<CallRecord>, ClientStats), String> {
    let client = ResilientClient::new(server, plan.client_cfg);
    let mut rng = Pcg::new(seed);
    let mut records = Vec::with_capacity(calls);
    let mut stats = ClientStats::default();
    for c in 0..calls {
        let spec = draw_call(&mut rng);
        let resp = client.eval_with_timeout(
            spec.function,
            spec.points.clone(),
            spec.engine,
            spec.stream_len,
            plan.call_timeout,
        );
        match &resp.error {
            None => {
                if spec.bad {
                    return Err(format!(
                        "call {c}: malformed request (fn={:?}, engine={:?}) was answered Ok",
                        spec.function, spec.engine
                    ));
                }
                if spec.engine == Engine::Xla {
                    return Err(format!(
                        "call {c}: Xla succeeded with no artifacts configured"
                    ));
                }
                check_payload(refs, plan, &spec, resp.degraded, &resp.outputs)
                    .map_err(|e| format!("call {c}: {e}"))?;
                stats.ok += 1;
                if resp.degraded {
                    stats.degraded_ok += 1;
                }
            }
            Some(e) => {
                let kind = error_kind(e);
                if spec.bad {
                    // Malformed calls must be refused at the edge (or
                    // fast-failed by an already-open breaker); anything
                    // else means validation let garbage through.
                    if !matches!(
                        e,
                        EvalError::Rejected(RejectReason::BadRequest(_)) | EvalError::CircuitOpen
                    ) {
                        return Err(format!(
                            "call {c}: malformed request answered {kind}, not BadRequest"
                        ));
                    }
                } else if matches!(e, EvalError::Rejected(RejectReason::BadRequest(_))) {
                    return Err(format!(
                        "call {c}: well-formed request (fn={:?}, engine={:?}, L={}) \
                         refused as BadRequest: {e}",
                        spec.function, spec.engine, spec.stream_len
                    ));
                }
                stats.count_error(kind);
            }
        }
        records.push(CallRecord {
            engine: spec.engine,
            degraded: resp.degraded,
            error: resp.error.as_ref().map(|e| error_kind(e).to_string()),
            outputs: resp.outputs,
        });
    }
    // Hedge losers that completed must match their winners bit-for-bit.
    let audit = client.drain_hedge_audits(Duration::from_millis(500));
    if audit.mismatched != 0 {
        return Err(format!(
            "hedge audit: {} loser(s) diverged from the winning payload",
            audit.mismatched
        ));
    }
    Ok((records, stats))
}

/// Poll until `f` returns true or `limit` elapses.
fn wait_until(limit: Duration, mut f: impl FnMut() -> bool) -> bool {
    let deadline = Instant::now() + limit;
    loop {
        if f() {
            return true;
        }
        if Instant::now() >= deadline {
            return false;
        }
        std::thread::sleep(Duration::from_millis(1));
    }
}

/// One full workload pass: build the stack from the plan, run the client
/// threads, drain, audit, shut down. Returns per-client records plus the
/// aggregated stats.
fn run_workload(
    seed: u64,
    plan: &RoundPlan,
    clients: usize,
    calls_per_client: usize,
) -> Result<(Vec<Vec<CallRecord>>, RoundReport), String> {
    let functions = build_functions()?;
    // Independent reference synthesis: the QP solve is deterministic, so
    // the served tables and the reference tables must agree bitwise —
    // any divergence would invalidate every payload check below.
    let mut refs = HashMap::new();
    for (name, served) in FUNCTION_NAMES.iter().zip(&functions) {
        let reference = build_functions()?
            .into_iter()
            .find(|f| f.name() == *name)
            .ok_or_else(|| format!("reference zoo lost function {name:?}"))?;
        let (a, b) = (served.coefficients(), reference.coefficients());
        if a.len() != b.len() || a.iter().zip(b).any(|(x, y)| x.to_bits() != y.to_bits()) {
            return Err(format!("synthesis is not deterministic for {name:?}"));
        }
        refs.insert(*name, reference);
    }

    let faults = Arc::new(FaultInjector::new());
    let sentinel = if plan.sentinel_enabled {
        SentinelConfig::default()
    } else {
        SentinelConfig::disabled()
    };
    let server = EvalServer::start(
        functions,
        None,
        ServerConfig {
            workers: plan.workers,
            policy: plan.policy,
            admission: plan.admission.clone(),
            faults: faults.clone(),
            sentinel,
            ..ServerConfig::default()
        },
    );
    arm_faults(plan, &faults);

    // Concurrent client threads; each one's workload is a pure function
    // of (round seed, client index).
    let mut results: Vec<Result<(Vec<CallRecord>, ClientStats), String>> = Vec::new();
    std::thread::scope(|scope| {
        let mut handles = Vec::new();
        for c in 0..clients {
            let client_seed = seed.wrapping_add((c as u64 + 1).wrapping_mul(GOLDEN_GAMMA));
            let server = &server;
            let refs = &refs;
            handles.push(scope.spawn(move || {
                run_client(server, refs, plan, client_seed, calls_per_client)
            }));
        }
        for h in handles {
            results.push(
                h.join()
                    .unwrap_or_else(|_| Err("client thread panicked".to_string())),
            );
        }
    });
    clear_faults(&faults);

    let mut records = Vec::new();
    let mut report = RoundReport { seed, plan: describe_plan(plan), ..RoundReport::default() };
    for r in results {
        let (recs, stats) = r?;
        report.calls += recs.len();
        report.ok += stats.ok;
        report.degraded_ok += stats.degraded_ok;
        for (kind, n) in stats.errors {
            if let Some(slot) = report.errors.iter_mut().find(|(k, _)| *k == kind) {
                slot.1 += n;
            } else {
                report.errors.push((kind, n));
            }
        }
        records.push(recs);
    }
    report.errors.sort();

    // --- Global invariants -------------------------------------------
    // Depth drained: abandoned (timed-out) requests are still answered
    // by the draining workers, releasing their admission tokens.
    if !wait_until(Duration::from_secs(10), || server.admission().total_depth() == 0) {
        return Err(format!(
            "round seed={seed:#x}: depth did not drain to 0 within 10s \
             (total_depth={})",
            server.admission().total_depth()
        ));
    }
    // Pool respawned to configured size after injected panics.
    if !wait_until(Duration::from_secs(5), || server.live_workers() == plan.workers) {
        return Err(format!(
            "round seed={seed:#x}: pool did not respawn to {} workers (live={})",
            plan.workers,
            server.live_workers()
        ));
    }
    let snap = server.metrics();
    snap.check_conservation()
        .map_err(|e| format!("round seed={seed:#x}: conservation (pre-shutdown): {e}"))?;
    // Sentinel legality: quarantine-degraded traffic implies a recorded
    // alarm; recoveries never outnumber alarms.
    if snap.drift_degraded > 0 && snap.drift_alarms == 0 {
        return Err(format!(
            "round seed={seed:#x}: {} drift-degraded answers with no drift alarm",
            snap.drift_degraded
        ));
    }
    if snap.drift_recoveries > snap.drift_alarms {
        return Err(format!(
            "round seed={seed:#x}: {} drift recoveries exceed {} alarms",
            snap.drift_recoveries, snap.drift_alarms
        ));
    }
    // Breaker legality: fast-fails imply a recorded open; hedge audits
    // (also checked per-thread) must show zero divergence globally.
    if snap.breaker_rejections > 0 && snap.breaker_opens == 0 {
        return Err(format!(
            "round seed={seed:#x}: {} breaker rejections with no recorded open",
            snap.breaker_rejections
        ));
    }
    if snap.client_hedge_mismatches != 0 {
        return Err(format!(
            "round seed={seed:#x}: {} hedge mismatches (determinism bug)",
            snap.client_hedge_mismatches
        ));
    }
    report.panics = snap.panics;
    report.respawns = snap.respawns;
    report.drift_alarms = snap.drift_alarms;
    report.breaker_opens = snap.breaker_opens;

    // Shutdown returns the final snapshot; the ledger must still balance
    // after the drain answers everything left in the queues.
    let last = server.shutdown();
    last.check_conservation()
        .map_err(|e| format!("round seed={seed:#x}: conservation (post-shutdown): {e}"))?;
    Ok((records, report))
}

/// Run one chaos round (and, when `opts.replay` is set, its
/// identical-seed replay) and audit every global invariant. `Err`
/// carries a one-line repro naming the round seed.
pub fn run_round(seed: u64, opts: &SoakOptions) -> Result<RoundReport, String> {
    let plan = draw_plan(seed);
    let (records, mut report) =
        run_workload(seed, &plan, opts.clients.max(1), opts.requests_per_client.max(1))?;
    if !opts.replay {
        return Ok(report);
    }
    // Determinism dividend: a fresh server from the identical seed must
    // produce byte-identical successful payloads. Timing-dependent
    // outcomes (timeouts, sheds) may differ between runs, so the
    // comparison is index-aligned and restricted to calls that
    // succeeded in both runs with the same degradation state — for
    // those, the payload is a pure function of the call spec.
    let (replayed, _) =
        run_workload(seed, &plan, opts.clients.max(1), opts.requests_per_client.max(1))?;
    if replayed.len() != records.len() {
        return Err(format!(
            "round seed={seed:#x}: replay produced {} client traces, expected {}",
            replayed.len(),
            records.len()
        ));
    }
    let mut compared = 0usize;
    for (c, (a_trace, b_trace)) in records.iter().zip(&replayed).enumerate() {
        if a_trace.len() != b_trace.len() {
            return Err(format!(
                "round seed={seed:#x}: client {c} issued {} calls on replay, expected {}",
                b_trace.len(),
                a_trace.len()
            ));
        }
        for (i, (a, b)) in a_trace.iter().zip(b_trace).enumerate() {
            if a.error.is_some() || b.error.is_some() || a.degraded != b.degraded {
                continue;
            }
            if a.outputs.len() != b.outputs.len()
                || a.outputs
                    .iter()
                    .zip(&b.outputs)
                    .any(|(x, y)| x.to_bits() != y.to_bits())
            {
                return Err(format!(
                    "round seed={seed:#x}: replay divergence at client {c} call {i}: \
                     {:?} vs {:?}",
                    a.outputs, b.outputs
                ));
            }
            compared += 1;
        }
    }
    report.replay_compared = compared;
    Ok(report)
}

/// Run `opts.rounds` independent rounds (each derived from `opts.seed`)
/// and return the per-round reports. Stops at the first violation; the
/// error names the failing round's seed so `run_round(seed, …)` is the
/// one-line repro. When replay is enabled, at least one payload pair
/// across the whole soak must actually have been compared — a soak
/// whose every call failed would otherwise vacuously "pass" replay.
pub fn run_soak(opts: &SoakOptions) -> Result<Vec<RoundReport>, String> {
    let mut reports = Vec::with_capacity(opts.rounds);
    for r in 0..opts.rounds {
        let seed = opts.seed.wrapping_add((r as u64).wrapping_mul(GOLDEN_GAMMA));
        reports.push(run_round(seed, opts)?);
    }
    if opts.replay && !reports.is_empty() {
        let compared: usize = reports.iter().map(|r| r.replay_compared).sum();
        if compared == 0 {
            return Err(
                "soak: replay enabled but zero payload pairs were comparable across all \
                 rounds (every call failed?) — the replay invariant was never exercised"
                    .to_string(),
            );
        }
    }
    Ok(reports)
}

#[cfg(test)]
mod tests {
    use super::*;

    /// The plan draw is a pure function of the seed.
    #[test]
    fn plan_draw_is_deterministic() {
        let a = draw_plan(42);
        let b = draw_plan(42);
        assert_eq!(format!("{a:?}"), format!("{b:?}"));
        assert!(a.workers >= 2 && a.workers <= 4);
        assert!(a.admission.shed_high >= 2);
        assert!(a.admission.shed_low >= 1 && a.admission.shed_low < a.admission.shed_high);
        if let Some(r) = a.client_cfg.retry {
            assert!(r.backoff_base <= r.backoff_max);
        }
    }

    /// The workload draw is deterministic and its malformed calls are
    /// really malformed (and its well-formed calls really well-formed).
    #[test]
    fn call_draw_is_deterministic_and_classified() {
        let mut a = Pcg::new(7);
        let mut b = Pcg::new(7);
        let mut saw_bad = false;
        let mut saw_good = false;
        for _ in 0..256 {
            let ca = draw_call(&mut a);
            let cb = draw_call(&mut b);
            assert_eq!(ca.function, cb.function);
            assert_eq!(ca.points, cb.points);
            assert_eq!(ca.stream_len, cb.stream_len);
            assert_eq!(ca.bad, cb.bad);
            let malformed = ca.function == "no_such_function"
                || ca.points.iter().any(|p| p.len() != 2)
                || ca.points.iter().flatten().any(|x| !x.is_finite());
            assert_eq!(ca.bad, malformed, "bad flag must match actual malformation");
            saw_bad |= ca.bad;
            saw_good |= !ca.bad;
            for p in &ca.points {
                if !ca.bad {
                    assert!(p.iter().all(|x| (0.0..=1.0).contains(x)));
                }
            }
            assert!(ca.stream_len > 0, "L=0 is excluded: engine rewrites make it route-dependent");
        }
        assert!(saw_bad && saw_good, "palette must mix malformed and well-formed calls");
    }

    /// A single fault-free mini-round end to end: all invariants green.
    #[test]
    fn clean_mini_round_passes_all_invariants() {
        // Seed chosen so the drawn fault mode is None (asserted below to
        // keep the test honest if the draw order ever changes).
        let mut seed = 1u64;
        while draw_plan(seed).fault != FaultMode::None {
            seed += 1;
        }
        let opts = SoakOptions { seed, rounds: 1, clients: 2, requests_per_client: 8, replay: true };
        let report = run_round(seed, &opts).expect("clean round must pass");
        assert_eq!(report.calls, 16);
        assert!(report.ok > 0, "a clean round must answer some calls successfully");
    }
}

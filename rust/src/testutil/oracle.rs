//! Differential oracle + shrinker over generated [`FuzzCase`]s.
//!
//! Per case the oracle asserts two kinds of relations:
//!
//! **Exact-equality lattice** (bit for bit, via `f64::to_bits`):
//! - scalar simulator replay: same seed → same bits;
//! - scalar == `eval_trials` at *every* compiled plane width (`u64`,
//!   `[u64; 4]`, and `MaxPlane` — `[u64; 8]` under `wide512`);
//! - scalar == the per-lane-threshold `eval_points` path (the
//!   coordinator's batch shape) with the point replicated per lane;
//! - scalar == TMR voting at fault rate 0 (the vote is the identity);
//! - scalar == armed-but-inert fault hooks (an attached all-zero
//!   [`BitFaultPlan`] must change nothing);
//! - every estimator route is one estimator: `eval_avg` ==
//!   `eval_avg_scalar` == wide `eval_avg` == `eval_avg_tmr`, and the
//!   same for `abs_error`.
//!
//! **Bounded relations**: the Monte-Carlo estimate sits within an
//! `L`-derived tolerance of the analytic closed form (Eq. 21) — a
//! deliberately generous band (the exactness burden is on the lattice;
//! this leg catches catastrophic divergence, NaNs, and sign flips).
//!
//! Real (non-inert) fault plans are checked for replay determinism and
//! range, not equality — fault entropy is per-lane by design, so scalar
//! and wide armed runs legitimately differ.
//!
//! On failure, [`run_seeded`] shrinks the case (drop variables → reduce
//! radices → shorten `L` → fewer trials → drop the plan → neutralize
//! table rows and inputs) under a bounded predicate-evaluation budget
//! and returns a report carrying the *minimized* seed + config — the
//! one-line repro contract.

use super::arbitrary::FuzzCase;
use crate::sc::fault::BitFaultPlan;
use crate::sc::plane::BitPlane;
use crate::smurf::analytic::AnalyticSmurf;
use crate::smurf::config::SmurfConfig;
use crate::smurf::sim::BitLevelSmurf;
use crate::smurf::sim_wide::{MaxPlane, WideBitLevelSmurf};
use crate::util::prng::GOLDEN_GAMMA;

/// Default predicate-evaluation budget of the shrinker: enough for the
/// generator's largest shapes to collapse, small enough that a failing
/// smoke run still exits in seconds.
pub const SHRINK_BUDGET: usize = 400;

/// One oracle violation: which leg of the lattice broke, and how.
#[derive(Clone, Debug)]
pub struct CheckFailure {
    /// Stable leg name (e.g. `wide-lattice`, `tmr-zero`, `armed-zero`).
    pub leg: &'static str,
    /// Human-readable divergence detail (values, lane, plane label).
    pub detail: String,
}

impl CheckFailure {
    fn new(leg: &'static str, detail: String) -> Self {
        Self { leg, detail }
    }

    /// Render as `[leg] detail` — the shape `run_seeded` reports.
    pub fn render(&self) -> String {
        format!("[{}] {}", self.leg, self.detail)
    }
}

/// Bitwise equality of two f64 slices; returns the first diverging lane.
fn first_divergence(a: &[f64], b: &[f64]) -> Option<(usize, f64, f64)> {
    a.iter()
        .zip(b.iter())
        .enumerate()
        .find(|(_, (x, y))| x.to_bits() != y.to_bits())
        .map(|(i, (&x, &y))| (i, x, y))
}

/// Run the full differential oracle over one case.
pub fn check_case(case: &FuzzCase) -> Result<(), CheckFailure> {
    let cfg = case.config();
    let clean = BitLevelSmurf::new(cfg.clone(), &case.w, case.mode);
    let analytic = AnalyticSmurf::new(cfg.clone(), case.w.clone());
    let seeds = case.trial_seeds(case.lattice_seeds);

    // Scalar reference column, plus range + replay determinism.
    let scalar: Vec<f64> =
        seeds.iter().map(|&s| clean.eval(&case.point, case.len, s)).collect();
    for (i, &y) in scalar.iter().enumerate() {
        if !(0.0..=1.0).contains(&y) {
            return Err(CheckFailure::new(
                "scalar-range",
                format!("trial {i}: output {y} outside [0,1]"),
            ));
        }
    }
    let replay: Vec<f64> =
        seeds.iter().map(|&s| clean.eval(&case.point, case.len, s)).collect();
    if let Some((i, a, b)) = first_divergence(&scalar, &replay) {
        return Err(CheckFailure::new(
            "scalar-replay",
            format!("trial {i}: {a} then {b} from the same seed"),
        ));
    }

    // Armed-zero at the scalar engine: an inert plan changes nothing.
    let armed = BitLevelSmurf::new(cfg.clone(), &case.w, case.mode)
        .with_fault_plan(BitFaultPlan::new(case.seed));
    let armed_out: Vec<f64> =
        seeds.iter().map(|&s| armed.eval(&case.point, case.len, s)).collect();
    if let Some((i, a, b)) = first_divergence(&scalar, &armed_out) {
        return Err(CheckFailure::new(
            "armed-zero",
            format!("scalar trial {i}: clean {a} != inert-armed {b}"),
        ));
    }
    // Same, with the case's own plan when it is armed but inert.
    if let Some(plan) = case.plan.as_ref().filter(|p| p.is_inert()) {
        let armed = BitLevelSmurf::new(cfg.clone(), &case.w, case.mode)
            .with_fault_plan(plan.clone());
        let out: Vec<f64> =
            seeds.iter().map(|&s| armed.eval(&case.point, case.len, s)).collect();
        if let Some((i, a, b)) = first_divergence(&scalar, &out) {
            return Err(CheckFailure::new(
                "armed-zero",
                format!("scalar trial {i}: clean {a} != case-plan(inert) {b}"),
            ));
        }
    }

    // Estimator identity: one estimator, every route.
    let avg = clean.eval_avg(&case.point, case.len, case.trials, case.seed);
    let avg_scalar =
        clean.eval_avg_scalar(&case.point, case.len, case.trials, case.seed);
    if avg.to_bits() != avg_scalar.to_bits() {
        return Err(CheckFailure::new(
            "estimator-routing",
            format!("eval_avg {avg} != eval_avg_scalar {avg_scalar}"),
        ));
    }
    let truth = analytic.eval(&case.point);
    let err_routed =
        clean.abs_error(&case.point, truth, case.len, case.trials, case.seed);
    let err_scalar =
        clean.abs_error_scalar(&case.point, truth, case.len, case.trials, case.seed);
    if err_routed.to_bits() != err_scalar.to_bits() {
        return Err(CheckFailure::new(
            "estimator-routing",
            format!("abs_error {err_routed} != abs_error_scalar {err_scalar}"),
        ));
    }

    // Every compiled plane width against the scalar column.
    check_plane::<u64>(case, &cfg, &scalar, &seeds, avg, "u64/64-lane")?;
    check_plane::<[u64; 4]>(case, &cfg, &scalar, &seeds, avg, "[u64;4]/256-lane")?;
    check_plane::<MaxPlane>(case, &cfg, &scalar, &seeds, avg, "MaxPlane")?;

    // Bounded relation against the closed form — only where the bound is
    // informative: enough trials to tame MC variance and a stream long
    // enough that the FSM warm-up transient (O(states/L)) is small.
    let states = cfg.num_aggregate_states();
    if case.trials >= 8 && case.len >= 16 * states {
        if !truth.is_finite() {
            return Err(CheckFailure::new(
                "analytic-bound",
                format!("analytic closed form returned {truth}"),
            ));
        }
        let tol = (0.05
            + 2.0 / (case.trials as f64).sqrt()
            + 2.0 * states as f64 / case.len as f64)
            .min(1.0);
        if (avg - truth).abs() > tol {
            return Err(CheckFailure::new(
                "analytic-bound",
                format!(
                    "bit-level mean {avg} vs analytic {truth}: |Δ|={} > tol={tol} \
                     (L={}, trials={}, states={states})",
                    (avg - truth).abs(),
                    case.len,
                    case.trials,
                ),
            ));
        }
    }

    // Real fault plans: deterministic replay and range, never equality
    // (fault entropy is per-lane by design).
    if let Some(plan) = case.plan.as_ref().filter(|p| !p.is_inert()) {
        let faulted = BitLevelSmurf::new(cfg.clone(), &case.w, case.mode)
            .with_fault_plan(plan.clone());
        let a: Vec<f64> =
            seeds.iter().map(|&s| faulted.eval(&case.point, case.len, s)).collect();
        let b: Vec<f64> =
            seeds.iter().map(|&s| faulted.eval(&case.point, case.len, s)).collect();
        if let Some((i, x, y)) = first_divergence(&a, &b) {
            return Err(CheckFailure::new(
                "fault-replay",
                format!("scalar trial {i}: {x} then {y} from the same seed + plan"),
            ));
        }
        if let Some(&y) = a.iter().find(|y| !(0.0..=1.0).contains(*y)) {
            return Err(CheckFailure::new(
                "fault-range",
                format!("faulted output {y} outside [0,1]"),
            ));
        }
        let wide = WideBitLevelSmurf::<u64>::new(cfg.clone(), &case.w, case.mode)
            .with_fault_plan(plan.clone());
        let mut st = wide.make_run_state();
        let mut wa = vec![0.0; seeds.len()];
        let mut wb = vec![0.0; seeds.len()];
        wide.eval_trials(&case.point, case.len, &seeds, &mut st, &mut wa);
        wide.eval_trials(&case.point, case.len, &seeds, &mut st, &mut wb);
        if let Some((i, x, y)) = first_divergence(&wa, &wb) {
            return Err(CheckFailure::new(
                "fault-replay",
                format!("wide lane {i}: {x} then {y} from the same seed + plan"),
            ));
        }
    }

    Ok(())
}

/// The per-plane-width legs: `eval_trials`, the per-lane `eval_points`
/// shape, TMR at rate 0, armed-zero, and the estimator routes — all
/// bit-equal to the scalar column / scalar estimate.
fn check_plane<P: BitPlane>(
    case: &FuzzCase,
    cfg: &SmurfConfig,
    scalar: &[f64],
    seeds: &[u64],
    scalar_avg: f64,
    label: &'static str,
) -> Result<(), CheckFailure> {
    let wide = WideBitLevelSmurf::<P>::new(cfg.clone(), &case.w, case.mode);
    let mut st = wide.make_run_state();
    let mut out = vec![0.0; seeds.len()];

    wide.eval_trials(&case.point, case.len, seeds, &mut st, &mut out);
    if let Some((i, a, b)) = first_divergence(scalar, &out) {
        return Err(CheckFailure::new(
            "wide-lattice",
            format!("{label} eval_trials lane {i}: scalar {a} != wide {b}"),
        ));
    }

    let pts: Vec<&[f64]> = vec![case.point.as_slice(); seeds.len()];
    wide.eval_points(&pts, case.len, seeds, &mut st, &mut out);
    if let Some((i, a, b)) = first_divergence(scalar, &out) {
        return Err(CheckFailure::new(
            "points-lattice",
            format!("{label} eval_points lane {i}: scalar {a} != wide {b}"),
        ));
    }

    // TMR with no plan: the vote is the identity, bit for bit.
    let k = seeds.len().min(P::LANES / 3).max(1);
    wide.eval_trials_tmr(&case.point, case.len, &seeds[..k], &mut st, &mut out);
    if let Some((i, a, b)) = first_divergence(&scalar[..k], &out[..k]) {
        return Err(CheckFailure::new(
            "tmr-zero",
            format!("{label} TMR trial {i}: scalar {a} != voted {b}"),
        ));
    }

    // Armed-but-inert plan on this plane width.
    let armed = WideBitLevelSmurf::<P>::new(cfg.clone(), &case.w, case.mode)
        .with_fault_plan(BitFaultPlan::new(case.seed));
    let mut st_armed = armed.make_run_state();
    armed.eval_trials(&case.point, case.len, seeds, &mut st_armed, &mut out);
    if let Some((i, a, b)) = first_divergence(scalar, &out) {
        return Err(CheckFailure::new(
            "armed-zero",
            format!("{label} lane {i}: clean scalar {a} != inert-armed wide {b}"),
        ));
    }

    // Estimator routes on this plane width.
    let avg = wide.eval_avg(&case.point, case.len, case.trials, case.seed, &mut st);
    if avg.to_bits() != scalar_avg.to_bits() {
        return Err(CheckFailure::new(
            "estimator-plane",
            format!("{label} eval_avg {avg} != scalar {scalar_avg}"),
        ));
    }
    let avg_tmr =
        wide.eval_avg_tmr(&case.point, case.len, case.trials, case.seed, &mut st);
    if avg_tmr.to_bits() != scalar_avg.to_bits() {
        return Err(CheckFailure::new(
            "estimator-tmr",
            format!("{label} eval_avg_tmr {avg_tmr} != scalar {scalar_avg}"),
        ));
    }
    Ok(())
}

/// Greedily minimize a failing case under a predicate-evaluation budget.
///
/// `fail` returns `Some(detail)` while the case still fails; the
/// shrinker only keeps mutations that preserve failure. Reduction order
/// (each pass repeats until the case is a fixed point or the budget is
/// spent): drop variables → reduce radices toward 2 → halve `L` → halve
/// trials → single lattice seed → drop the fault plan → simplest entropy
/// mode → neutralize input coordinates → neutralize table rows to 0.5.
/// Returns the minimized case and its failure detail.
pub fn shrink_case<F>(
    start: FuzzCase,
    start_detail: String,
    fail: &F,
    budget: usize,
) -> (FuzzCase, String)
where
    F: Fn(&FuzzCase) -> Option<String>,
{
    let mut case = start;
    let mut detail = start_detail;
    let mut left = budget;
    loop {
        let mut improved = false;

        // Drop whole variables (highest index first; restart after a win
        // because indices shift).
        let mut j = case.radices.len();
        while j > 0 && left > 0 {
            j -= 1;
            if case.radices.len() <= 1 {
                break;
            }
            if accept(&drop_var(&case, j), fail, &mut left, &mut case, &mut detail) {
                improved = true;
                j = case.radices.len();
            }
        }

        // Reduce each radix toward 2.
        for j in 0..case.radices.len() {
            while case.radices[j] > 2 && left > 0 {
                if !accept(&reduce_radix(&case, j), fail, &mut left, &mut case, &mut detail) {
                    break;
                }
                improved = true;
            }
        }

        // Shorten the stream.
        while case.len > 1 && left > 0 {
            let mut cand = case.clone();
            cand.len /= 2;
            if !accept(&cand, fail, &mut left, &mut case, &mut detail) {
                break;
            }
            improved = true;
        }

        // Fewer estimator trials and lattice seeds.
        while case.trials > 1 && left > 0 {
            let mut cand = case.clone();
            cand.trials /= 2;
            if !accept(&cand, fail, &mut left, &mut case, &mut detail) {
                break;
            }
            improved = true;
        }
        if case.lattice_seeds > 1 && left > 0 {
            let mut cand = case.clone();
            cand.lattice_seeds = 1;
            improved |= accept(&cand, fail, &mut left, &mut case, &mut detail);
        }

        // Drop the fault plan, then the entropy mode's complexity.
        if case.plan.is_some() && left > 0 {
            let mut cand = case.clone();
            cand.plan = None;
            improved |= accept(&cand, fail, &mut left, &mut case, &mut detail);
        }
        if case.mode != crate::smurf::sim::EntropyMode::SharedLfsr && left > 0 {
            let mut cand = case.clone();
            cand.mode = crate::smurf::sim::EntropyMode::SharedLfsr;
            improved |= accept(&cand, fail, &mut left, &mut case, &mut detail);
        }

        // Neutralize input coordinates (0.0, then 0.5).
        for j in 0..case.point.len() {
            for v in [0.0, 0.5] {
                if left == 0 || case.point[j].to_bits() == v.to_bits() {
                    continue;
                }
                let mut cand = case.clone();
                cand.point[j] = v;
                improved |= accept(&cand, fail, &mut left, &mut case, &mut detail);
            }
        }

        // Neutralize table rows to the midpoint.
        for i in 0..case.w.len() {
            if left == 0 || case.w[i] == 0.5 {
                continue;
            }
            let mut cand = case.clone();
            cand.w[i] = 0.5;
            improved |= accept(&cand, fail, &mut left, &mut case, &mut detail);
        }

        if !improved || left == 0 {
            return (case, detail);
        }
    }
}

/// Spend one budget unit on `cand`; keep it iff it still fails.
fn accept<F>(
    cand: &FuzzCase,
    fail: &F,
    left: &mut usize,
    case: &mut FuzzCase,
    detail: &mut String,
) -> bool
where
    F: Fn(&FuzzCase) -> Option<String>,
{
    if *left == 0 {
        return false;
    }
    *left -= 1;
    match fail(cand) {
        Some(d) => {
            *case = cand.clone();
            *detail = d;
            true
        }
        None => false,
    }
}

/// Remove variable `j`, keeping the table slice at digit `0` (mixed-radix
/// LSB-first convention, matching [`SmurfConfig::strides`]).
fn drop_var(case: &FuzzCase, j: usize) -> FuzzCase {
    let mut cand = case.clone();
    cand.radices.remove(j);
    cand.point.remove(j);
    cand.w = table_with_digit(&case.radices, &case.w, j, |_r| 0);
    cand
}

/// Shrink variable `j`'s radix by one, keeping the rows whose digit `j`
/// is still representable.
fn reduce_radix(case: &FuzzCase, j: usize) -> FuzzCase {
    let mut cand = case.clone();
    cand.radices[j] -= 1;
    cand.w = remap_table(&case.radices, &case.w, &cand.radices);
    cand
}

/// Project `old_w` onto the radices with variable `j` removed, fixing
/// its digit via `fixed(radix)`.
fn table_with_digit(
    old_radices: &[usize],
    old_w: &[f64],
    j: usize,
    fixed: impl Fn(usize) -> usize,
) -> Vec<f64> {
    let new_states: usize =
        old_radices.iter().enumerate().filter(|&(k, _)| k != j).map(|(_, &r)| r).product();
    let mut out = Vec::with_capacity(new_states.max(1));
    for idx in 0..new_states.max(1) {
        let mut rem = idx;
        let mut old_idx = 0;
        let mut old_stride = 1;
        for (k, &r) in old_radices.iter().enumerate() {
            let d = if k == j {
                fixed(r)
            } else {
                let d = rem % r;
                rem /= r;
                d
            };
            old_idx += d * old_stride;
            old_stride *= r;
        }
        out.push(old_w[old_idx]);
    }
    out
}

/// Re-index `old_w` onto smaller per-variable radices (same variable
/// count), keeping the rows every surviving digit combination selects.
fn remap_table(old_radices: &[usize], old_w: &[f64], new_radices: &[usize]) -> Vec<f64> {
    let new_states: usize = new_radices.iter().product();
    let mut out = Vec::with_capacity(new_states);
    for idx in 0..new_states {
        let mut rem = idx;
        let mut old_idx = 0;
        let mut old_stride = 1;
        for (k, &r_old) in old_radices.iter().enumerate() {
            let d = rem % new_radices[k];
            rem /= new_radices[k];
            old_idx += d * old_stride;
            old_stride *= r_old;
        }
        out.push(old_w[old_idx]);
    }
    out
}

/// Render the minimized repro block `run_seeded` (and the example
/// driver) print before failing.
pub fn minimized_report(case: &FuzzCase, detail: &str) -> String {
    format!(
        "MINIMIZED REPRO\n  case: {}\n  failure: {}\n  note: the seed regenerates the \
         ORIGINAL case (FuzzCase::from_seed); the fields above are the minimized case.",
        case.describe(),
        detail,
    )
}

/// Run the oracle over `cases` seeds derived from `base_seed` by
/// golden-gamma stepping. On the first failure the case is shrunk under
/// [`SHRINK_BUDGET`] and the returned error carries the original case,
/// the original failure, and the minimized repro block. `Ok` carries the
/// number of cases checked.
pub fn run_seeded(base_seed: u64, cases: usize) -> Result<usize, String> {
    let fail = |c: &FuzzCase| check_case(c).err().map(|f| f.render());
    for i in 0..cases {
        let seed = base_seed.wrapping_add((i as u64).wrapping_mul(GOLDEN_GAMMA));
        let case = FuzzCase::from_seed(seed);
        if let Some(first) = fail(&case) {
            let (min_case, min_detail) =
                shrink_case(case.clone(), first.clone(), &fail, SHRINK_BUDGET);
            return Err(format!(
                "differential oracle failed at case {i}/{cases} (base_seed={base_seed:#x})\n\
                 original: {}\n  original failure: {first}\n{}",
                case.describe(),
                minimized_report(&min_case, &min_detail),
            ));
        }
    }
    Ok(cases)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::smurf::sim::EntropyMode;

    #[test]
    fn oracle_accepts_a_seed_sweep() {
        // A real (if small) slice of the fuzz space must be green; the
        // full sweep runs via `make fuzz-smoke` / tests/soak.rs.
        if let Err(report) = run_seeded(0x0D0E_u64, 6) {
            panic!("oracle rejected a healthy stack:\n{report}");
        }
    }

    #[test]
    fn shrinker_minimizes_a_perturbed_theta_table() {
        // Simulated engine bug: a "buggy build" perturbs θ row 0 by half
        // the quantization grid (always a real threshold change). The
        // predicate fails whenever clean and buggy outputs diverge; the
        // shrinker must keep the failure while collapsing the case, and
        // the report must carry the minimized repro.
        let fail = |c: &FuzzCase| {
            let cfg = c.config();
            let clean = crate::smurf::sim::BitLevelSmurf::new(cfg.clone(), &c.w, c.mode);
            let mut w2 = c.w.clone();
            w2[0] = if w2[0] >= 0.5 { w2[0] - 0.5 } else { w2[0] + 0.5 };
            let buggy = crate::smurf::sim::BitLevelSmurf::new(cfg, &w2, c.mode);
            let s = c.trial_seeds(1)[0];
            let a = clean.eval(&c.point, c.len, s);
            let b = buggy.eval(&c.point, c.len, s);
            (a.to_bits() != b.to_bits())
                .then(|| format!("θ row 0 perturbation diverges: clean {a} vs buggy {b}"))
        };
        // Deterministically find a failing start in the normal sweep.
        let mut start = None;
        for i in 0..64u64 {
            let c = FuzzCase::from_seed(
                0xBAD_7AB1E_u64.wrapping_add(i.wrapping_mul(crate::util::prng::GOLDEN_GAMMA)),
            );
            if fail(&c).is_some() {
                start = Some(c);
                break;
            }
        }
        let start = start.expect("a θ-row-0 perturbation must diverge somewhere in 64 cases");
        let first = fail(&start).unwrap();
        let (min, detail) = shrink_case(start.clone(), first, &fail, SHRINK_BUDGET);
        // Still failing, and no larger on any axis the shrinker drives.
        assert!(fail(&min).is_some(), "shrunk case must still fail");
        let start_states: usize = start.radices.iter().product();
        let min_states: usize = min.radices.iter().product();
        assert!(min_states <= start_states);
        assert!(min.len <= start.len);
        assert!(min.trials <= start.trials);
        assert!(min.radices.len() <= start.radices.len());
        let report = minimized_report(&min, &detail);
        assert!(report.contains("MINIMIZED REPRO"));
        assert!(report.contains("seed="));
        assert!(report.contains("diverges"));
    }

    #[test]
    fn shrinker_is_a_fixed_point_on_a_minimal_case() {
        // A case that always fails cannot shrink below the floor:
        // one binary variable, L=1, one trial, no plan.
        let fail = |_: &FuzzCase| Some("always".to_string());
        let floor = FuzzCase {
            seed: 0x1,
            radices: vec![2],
            w: vec![0.5, 0.5],
            mode: EntropyMode::SharedLfsr,
            point: vec![0.0],
            len: 1,
            trials: 1,
            lattice_seeds: 1,
            plan: None,
        };
        let (min, _) = shrink_case(floor.clone(), "always".into(), &fail, 64);
        assert_eq!(min, floor);
    }

    #[test]
    fn table_projections_follow_the_stride_convention() {
        // radices [2, 3]: strides [1, 2]; w[i0 + 2*i1].
        let w: Vec<f64> = (0..6).map(|i| i as f64).collect();
        // Drop variable 1 at digit 0 → rows {0, 1}.
        assert_eq!(table_with_digit(&[2, 3], &w, 1, |_| 0), vec![0.0, 1.0]);
        // Drop variable 0 at digit 0 → rows {0, 2, 4}.
        assert_eq!(table_with_digit(&[2, 3], &w, 0, |_| 0), vec![0.0, 2.0, 4.0]);
        // Reduce variable 1's radix 3 → 2: digits {0, 1} survive.
        assert_eq!(remap_table(&[2, 3], &w, &[2, 2]), vec![0.0, 1.0, 2.0, 3.0]);
    }
}

//! Structured, seed-deterministic generators for the differential
//! oracle.
//!
//! One [`Pcg`] seed expands into a complete [`FuzzCase`]: SMURF shape
//! (variable count, mixed radices), a θ/CPT table that deliberately
//! includes the boundary rows 0.0 and 1.0 (quantizing to gate thresholds
//! 0 and 65535), hostile inputs (±0.0, subnormals, `f64::MIN_POSITIVE`,
//! exactly-representable `k/65536` grid points, `1 − ε`), lane-boundary
//! stream lengths (1, 63, 64, 65, 4096), an entropy mode, a trial
//! budget, and an optional [`BitFaultPlan`] (absent, armed-but-inert, or
//! genuinely faulty). Every case carries its seed: re-running
//! [`FuzzCase::from_seed`] with the same value rebuilds the identical
//! case, so any oracle failure is a one-line repro.
//!
//! Generation draws from `Pcg` only — no wall clock, no OS entropy — and
//! every case is *valid* by construction (arity and table sizes match,
//! radices ≥ 2, probabilities within the simulator's accepted domain),
//! so an engine assertion firing on a generated case is itself a bug.

use crate::sc::fault::{BitFaultPlan, FaultRates, FaultSite};
use crate::smurf::config::SmurfConfig;
use crate::smurf::sim::EntropyMode;
use crate::util::prng::{Pcg, GOLDEN_GAMMA};

/// Cap on the generated CPT bank size `Π N_j`. Keeps every case's table
/// (and the oracle's per-case cost) bounded while still reaching
/// four-variable and radix-16 shapes.
pub const MAX_AGGREGATE_STATES: usize = 512;

/// Work cap per case: `len · trials` of the estimator legs never exceeds
/// this, so a full smoke sweep stays inside tier-1 time even in debug
/// builds.
pub const MAX_ESTIMATOR_CYCLES: usize = 32_768;

/// One fully-specified differential-oracle case. All fields are public
/// so the shrinker (and hand-written boundary regressions) can construct
/// and mutate cases directly; a mutated case is still a valid case, it
/// just no longer derives from `seed` alone — which is why failure
/// reports always print [`FuzzCase::describe`], never just the seed.
#[derive(Clone, Debug, PartialEq)]
pub struct FuzzCase {
    /// Generator seed (also the base of the per-trial stream seeds).
    pub seed: u64,
    /// Per-variable FSM radices (each ≥ 2, product ≤
    /// [`MAX_AGGREGATE_STATES`]).
    pub radices: Vec<usize>,
    /// θ/CPT table, one coefficient in `[0, 1]` per aggregate state.
    pub w: Vec<f64>,
    /// Entropy wiring of the bit-level engines.
    pub mode: EntropyMode,
    /// Input point, one probability per variable.
    pub point: Vec<f64>,
    /// Bitstream length `L` (≥ 1).
    pub len: usize,
    /// Monte-Carlo trials for the estimator legs (≥ 1).
    pub trials: usize,
    /// Independent stream seeds exercised by the exact-lattice legs
    /// (1..=8; also the TMR trial count, so always ≤ `LANES / 3`).
    pub lattice_seeds: usize,
    /// Optional fault plan: `None`, armed-but-inert, or real rates.
    pub plan: Option<BitFaultPlan>,
}

impl FuzzCase {
    /// Deterministically expand `seed` into a complete case.
    pub fn from_seed(seed: u64) -> Self {
        let mut rng = Pcg::new(seed);
        let radices = gen_radices(&mut rng);
        let states: usize = radices.iter().product();
        let w = gen_table(&mut rng, states);
        let mode = match rng.below(3) {
            0 => EntropyMode::SharedLfsr,
            1 => EntropyMode::IndependentXorshift,
            _ => EntropyMode::SobolCpt,
        };
        let point: Vec<f64> = (0..radices.len()).map(|_| gen_probability(&mut rng)).collect();
        let len = gen_len(&mut rng);
        let trials = gen_trials(&mut rng, len);
        let lattice_seeds = 1 + rng.below(8) as usize;
        let plan = gen_plan(&mut rng);
        Self { seed, radices, w, mode, point, len, trials, lattice_seeds, plan }
    }

    /// The case's [`SmurfConfig`] (rebuilt on demand — the shrinker
    /// mutates `radices` and `w` together).
    pub fn config(&self) -> SmurfConfig {
        SmurfConfig::new(self.radices.clone())
    }

    /// `n` independent stream seeds derived from the case seed by golden
    /// -gamma stepping — the seed set the exact-lattice legs run at.
    pub fn trial_seeds(&self, n: usize) -> Vec<u64> {
        (0..n as u64)
            .map(|i| self.seed.wrapping_add((i + 1).wrapping_mul(GOLDEN_GAMMA)))
            .collect()
    }

    /// One-line, complete repro: every field a reader needs to rebuild
    /// the case by hand (the seed alone suffices for *generated* cases;
    /// shrunk cases need the explicit fields).
    pub fn describe(&self) -> String {
        format!(
            "seed={:#018x} radices={:?} mode={:?} len={} trials={} lattice_seeds={} \
             point={:?} w={:?} plan={}",
            self.seed,
            self.radices,
            self.mode,
            self.len,
            self.trials,
            self.lattice_seeds,
            self.point,
            self.w,
            describe_plan(&self.plan),
        )
    }
}

/// Render the fault plan compactly for repro lines.
fn describe_plan(plan: &Option<BitFaultPlan>) -> String {
    match plan {
        None => "none".to_string(),
        Some(p) => {
            let mut sites = String::new();
            for site in FaultSite::ALL {
                let r = p.rates(site);
                if r != FaultRates::NONE {
                    sites.push_str(&format!(
                        " {site:?}(s0={},s1={},flip={})",
                        r.stuck_at_zero, r.stuck_at_one, r.flip
                    ));
                }
            }
            let tag = if p.is_inert() { " inert" } else { "" };
            format!("{{seed={:#x}{}{}}}", p.seed(), sites, tag)
        }
    }
}

/// Mixed radices from a hostile palette (binary through radix-16),
/// truncated so the CPT bank stays within [`MAX_AGGREGATE_STATES`].
fn gen_radices(rng: &mut Pcg) -> Vec<usize> {
    let target_vars = 1 + rng.below(4) as usize;
    let mut radices = Vec::with_capacity(target_vars);
    let mut states = 1usize;
    for _ in 0..target_vars {
        let candidate = match rng.below(8) {
            0 => 2,
            1 => 3,
            2 => 4,
            3 => 5,
            4 => 6,
            5 => 8,
            6 => 12,
            _ => 16,
        };
        // Prefer keeping the variable at a smaller radix over dropping it.
        let r = if states * candidate <= MAX_AGGREGATE_STATES {
            candidate
        } else if states * 2 <= MAX_AGGREGATE_STATES {
            2
        } else {
            break;
        };
        radices.push(r);
        states *= r;
    }
    if radices.is_empty() {
        radices.push(2);
    }
    radices
}

/// θ/CPT table over a hostile palette; with probability 1/2 both
/// boundary rows (0.0 → gate 0, 1.0 → gate 65535) are forced present.
fn gen_table(rng: &mut Pcg, states: usize) -> Vec<f64> {
    let mut w: Vec<f64> = (0..states).map(|_| gen_probability(rng)).collect();
    if states >= 2 && rng.below(2) == 0 {
        let i0 = rng.below(states as u64) as usize;
        let i1 = (i0 + 1 + rng.below(states as u64 - 1) as usize) % states;
        w[i0] = 0.0;
        w[i1] = 1.0;
    }
    w
}

/// One probability from the hostile palette: domain edges, signed zero,
/// subnormals, the smallest normal, exactly-representable grid points,
/// off-by-ε values, and plain uniforms.
fn gen_probability(rng: &mut Pcg) -> f64 {
    match rng.below(12) {
        0 => 0.0,
        1 => 1.0,
        2 => -0.0,
        3 => 5e-324,              // smallest positive subnormal
        4 => f64::MIN_POSITIVE,   // smallest positive normal
        5 => rng.below(65_537) as f64 / 65_536.0, // exact θ-grid point
        6 => 1.0 - f64::EPSILON,
        7 => 0.5 + f64::EPSILON,
        8 => f64::EPSILON,
        _ => rng.uniform(),
    }
}

/// Stream length: the lane boundaries of the 64-wide plane (63/64/65),
/// the degenerate single-cycle stream, the paper-scale 4096, and
/// uniform fillers.
fn gen_len(rng: &mut Pcg) -> usize {
    match rng.below(8) {
        0 => 1,
        1 => 63,
        2 => 64,
        3 => 65,
        4 => 4096,
        _ => 2 + rng.below(510) as usize,
    }
}

/// Trial budget for the estimator legs, straddling the scalar↔wide
/// routing threshold (`WIDE_TRIALS_MIN = 8`) and one full plane (64),
/// clamped so `len · trials` ≤ [`MAX_ESTIMATOR_CYCLES`].
fn gen_trials(rng: &mut Pcg, len: usize) -> usize {
    let t = match rng.below(6) {
        0 => 1,
        1 => 2,
        2 => 7,
        3 => 8,
        4 => 64,
        _ => 9 + rng.below(57) as usize,
    };
    t.min((MAX_ESTIMATOR_CYCLES / len).max(1))
}

/// Fault plan: absent (half the cases — the clean lattice), armed but
/// inert (the armed-zero legs), sub-quantization rates (inert by the
/// 16-bit grid), or real rates at one random site.
fn gen_plan(rng: &mut Pcg) -> Option<BitFaultPlan> {
    match rng.below(8) {
        0 | 1 | 2 | 3 => None,
        4 => Some(BitFaultPlan::new(rng.next_u64())),
        5 => Some(BitFaultPlan::uniform(rng.next_u64(), FaultRates::flips(1e-9))),
        _ => {
            let site = FaultSite::ALL[rng.below(FaultSite::COUNT as u64) as usize];
            let rate = 2f64.powi(-(3 + rng.below(8) as i32));
            let rates = match rng.below(3) {
                0 => FaultRates::flips(rate),
                1 => FaultRates::stuck0(rate),
                _ => FaultRates::stuck1(rate),
            };
            Some(BitFaultPlan::new(rng.next_u64()).with_site(site, rates))
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cases_are_reproducible_from_their_seed() {
        for i in 0..64u64 {
            let seed = 0xF022_CA5E_u64.wrapping_add(i.wrapping_mul(GOLDEN_GAMMA));
            let a = FuzzCase::from_seed(seed);
            let b = FuzzCase::from_seed(seed);
            assert_eq!(a, b);
            assert_eq!(a.describe(), b.describe());
        }
    }

    #[test]
    fn every_generated_case_is_valid() {
        for i in 0..256u64 {
            let case = FuzzCase::from_seed(0xA11D_u64.wrapping_add(i.wrapping_mul(GOLDEN_GAMMA)));
            let states: usize = case.radices.iter().product();
            assert!(!case.radices.is_empty() && case.radices.iter().all(|&r| r >= 2));
            assert!(states <= MAX_AGGREGATE_STATES);
            assert_eq!(case.w.len(), states);
            assert_eq!(case.point.len(), case.radices.len());
            assert!(case.w.iter().all(|&v| (0.0..=1.0).contains(&v)));
            assert!(case.point.iter().all(|&v| (0.0..=1.0).contains(&v)));
            assert!(case.len >= 1);
            assert!(case.trials >= 1 && case.len * case.trials <= MAX_ESTIMATOR_CYCLES);
            assert!((1..=8).contains(&case.lattice_seeds));
            // The config constructor's own validation must accept it.
            let cfg = case.config();
            assert_eq!(cfg.num_aggregate_states(), states);
        }
    }

    #[test]
    fn palette_reaches_the_hostile_corners() {
        // Across a modest sweep the generator must actually emit the
        // boundary rows, a degenerate stream, a lane-boundary stream,
        // and at least one real fault plan — otherwise the "hostile"
        // palette is decorative.
        let mut saw_zero_row = false;
        let mut saw_one_row = false;
        let mut saw_len_one = false;
        let mut saw_lane_edge = false;
        let mut saw_real_plan = false;
        let mut saw_inert_plan = false;
        for i in 0..512u64 {
            let case = FuzzCase::from_seed(0xED6E_u64.wrapping_add(i.wrapping_mul(GOLDEN_GAMMA)));
            saw_zero_row |= case.w.contains(&0.0);
            saw_one_row |= case.w.contains(&1.0);
            saw_len_one |= case.len == 1;
            saw_lane_edge |= matches!(case.len, 63 | 64 | 65);
            if let Some(p) = &case.plan {
                saw_real_plan |= !p.is_inert();
                saw_inert_plan |= p.is_inert();
            }
        }
        assert!(saw_zero_row && saw_one_row, "θ boundary rows never generated");
        assert!(saw_len_one, "L=1 never generated");
        assert!(saw_lane_edge, "lane-boundary L never generated");
        assert!(saw_real_plan && saw_inert_plan, "fault-plan palette incomplete");
    }
}

//! `cargo run -p xtask -- verify` — run the repo-invariant lint pass.
//!
//! Exit status: 0 when the tree is clean, 1 with a finding listing
//! otherwise, 2 on usage errors. CI runs this in the `static-analysis`
//! job; locally it is `make lint-invariants` (and part of
//! `make verify-all`).

use std::path::Path;
use std::process::ExitCode;

fn main() -> ExitCode {
    let args: Vec<String> = std::env::args().skip(1).collect();
    match args.first().map(String::as_str) {
        Some("verify") => verify(),
        _ => {
            eprintln!("usage: cargo run -p xtask -- verify");
            eprintln!();
            eprintln!("Runs the repo-invariant static-analysis pass over rust/src");
            eprintln!("(rules and rationale: docs/INVARIANTS.md).");
            ExitCode::from(2)
        }
    }
}

fn verify() -> ExitCode {
    // The xtask crate lives at <repo>/xtask, so the repo root is its
    // parent; compile-time resolution keeps this independent of cwd.
    let root = Path::new(env!("CARGO_MANIFEST_DIR"))
        .parent()
        .expect("xtask sits one level below the repo root");
    match xtask::verify_repo(root) {
        Ok(findings) if findings.is_empty() => {
            println!("xtask verify: OK — no invariant violations in rust/src");
            ExitCode::SUCCESS
        }
        Ok(findings) => {
            for f in &findings {
                println!("{f}");
            }
            println!();
            println!(
                "xtask verify: {} violation(s); see docs/INVARIANTS.md for each rule's \
                 rationale and the `xtask: allow(<rule>) justification: …` waiver syntax",
                findings.len()
            );
            ExitCode::FAILURE
        }
        Err(e) => {
            eprintln!("xtask verify: cannot walk rust/src: {e}");
            ExitCode::FAILURE
        }
    }
}

//! Repo-invariant lint engine behind `cargo run -p xtask -- verify`.
//!
//! Six rules, each enforcing an invariant the compiler and clippy cannot
//! see (the full catalogue, with rationale and cross-references to the
//! dynamic checks, lives in `docs/INVARIANTS.md`):
//!
//! - **no-panic** — non-test code in `rust/src/coordinator/` and
//!   `rust/src/testutil/` must not call `.unwrap()`, `.expect(…)`,
//!   `panic!`, `unreachable!`, `todo!` or `unimplemented!`: the serving
//!   core's contract is that every failure is a *typed* answer
//!   (`EvalError`/`RejectReason`), and a stray panic in the supervisor
//!   or submit path would take down threads the chaos suite proves must
//!   survive. The robustness harness inherits the rule because it is
//!   production-compiled library code: its failures are `Result<_,
//!   String>` repro reports, and only the calling tests/drivers panic.
//! - **hot-alloc** — inside `// xtask: hot-loop` … `// xtask:
//!   hot-loop-end` marker regions (the per-clock kernels and the
//!   batcher's steady-state arrival path), no fresh heap allocation:
//!   `Vec::new`, `vec![`, `.to_vec(`, `Box::new`, `.collect`,
//!   `with_capacity`, `String::new`, `format!`. Buffers must come from
//!   caller-owned scratch; amortized reuse (`clear`/`push` on retained
//!   capacity, `clone` of existing values) is allowed by design.
//! - **seed-literal** — the contract seed constants (`0x5EED`,
//!   `0x9E3779B97F4A7C15`) appear in non-test code only on their `pub
//!   const` definition lines (`DEFAULT_STREAM_SEED`, `GOLDEN_GAMMA`,
//!   `STREAM_SEED_STRIDE`); everything else must reference the named
//!   constant. Tests/benches keep raw literals deliberately — they pin
//!   the contract from the outside.
//! - **plane-default** — the width-generic modules (bit-plane substrate,
//!   wide engines) must not hardcode `::<u64>` outside test code: every
//!   width-parametric suite fans out through `for_each_plane_width!`,
//!   whose single registration line carries the one sanctioned waiver.
//! - **doc-failure** — every non-test `pub fn` in `rust/src/coordinator/`
//!   carries a `///` doc, and any whose *return type* names `EvalError`
//!   or `RejectReason` must name that type in the doc: the typed failure
//!   model is API surface, not an implementation detail.
//! - **allow-attr** — a `#[allow(…)]` in non-test code needs a
//!   `// justification: …` comment on the same line or in the comment
//!   block directly above (the lint policy in `rust/src/lib.rs`).
//!
//! Any rule can be waived at a specific line with
//! `// xtask: allow(<rule>) justification: <why>` on the flagged line or
//! in the contiguous comment block directly above it — a waiver without
//! a reason does not parse.
//!
//! # Scope and simplifications (deliberate)
//!
//! The engine is plain line analysis — no parser, zero dependencies —
//! which is exactly enough because the repo follows two conventions the
//! engine leans on:
//!
//! - **Test code is trailing.** A file's tests live in one `mod tests`
//!   under an *unindented* `#[cfg(test)]` (or `#[cfg(all(test, …))]`)
//!   attribute at the end of the file; everything from that line down is
//!   exempt from every rule. Indented `#[cfg(test)]` items (test-only
//!   helper methods inside an impl) do *not* end the checked region.
//! - **Comments are line comments.** `//` comments are stripped (string
//!   literals are respected); block comments `/* … */` are not used in
//!   this repo and are not handled.

use std::fmt;
use std::path::Path;

/// One rule violation at a specific line.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Finding {
    /// Rule identifier (`no-panic`, `hot-alloc`, `seed-literal`,
    /// `plane-default`, `doc-failure`, `allow-attr`).
    pub rule: &'static str,
    /// Path relative to the repo root, `/`-separated.
    pub path: String,
    /// 1-based line number.
    pub line: usize,
    /// Human-readable description of the violation.
    pub message: String,
}

impl fmt::Display for Finding {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}:{}: [{}] {}", self.path, self.line, self.rule, self.message)
    }
}

/// The generic bit-plane modules covered by the `plane-default` rule:
/// hardcoding `::<u64>` in one of these silently drops the wider planes
/// from whatever it parameterizes.
const PLANE_GENERIC_MODULES: &[&str] = &[
    "rust/src/sc/plane.rs",
    "rust/src/sc/rng.rs",
    "rust/src/sc/sng.rs",
    "rust/src/sc/cpt.rs",
    "rust/src/sc/pwmm_wide.rs",
    "rust/src/sc/fault.rs",
    "rust/src/fsm/chain_wide.rs",
    "rust/src/smurf/sim_wide.rs",
];

/// Panicking calls banned from the serving core's non-test code.
const PANIC_TOKENS: &[&str] = &[
    ".unwrap()",
    ".expect(",
    "panic!(",
    "unreachable!(",
    "todo!(",
    "unimplemented!(",
];

/// Fresh-allocation calls banned inside `xtask: hot-loop` regions.
/// Amortized reuse (`clear`, `push`, `resize` on retained capacity,
/// `clone`) is deliberately absent from this list.
const ALLOC_TOKENS: &[&str] = &[
    "Vec::new",
    "vec![",
    ".to_vec(",
    "Box::new",
    ".collect(",
    ".collect::",
    "with_capacity",
    "String::new",
    "format!(",
];

/// Strip a trailing `//` line comment, respecting double-quoted string
/// literals (a `//` inside a string is code, not a comment).
fn strip_comment(line: &str) -> &str {
    let bytes = line.as_bytes();
    let mut in_str = false;
    let mut i = 0;
    while i < bytes.len() {
        match bytes[i] {
            b'\\' if in_str => i += 1, // skip the escaped byte
            b'"' => in_str = !in_str,
            b'/' if !in_str && i + 1 < bytes.len() && bytes[i + 1] == b'/' => {
                return &line[..i];
            }
            _ => {}
        }
        i += 1;
    }
    line
}

/// Index of the first *unindented* `#[cfg(test)]`-family line (the
/// repo's trailing-test-mod convention); lines at or after it are exempt
/// from every rule. `len` when the file has no trailing test section.
fn test_section_start(lines: &[&str]) -> usize {
    lines
        .iter()
        .position(|l| l.starts_with("#[cfg(test)]") || l.starts_with("#[cfg(all(test"))
        .unwrap_or(lines.len())
}

/// True if `lines[idx]` carries an `xtask: allow(<rule>)` waiver — on
/// the line itself or in the contiguous `//` comment block directly
/// above. The waiver must carry a `justification:` to parse at all.
fn has_waiver(lines: &[&str], idx: usize, rule: &str) -> bool {
    let tag = format!("xtask: allow({rule})");
    // The justification may trail on the tag line or on a continuation
    // comment line: the tag is matched here, the justification anywhere
    // in the same block (`block_has_justification`).
    let is_waiver = |l: &str| l.contains(tag.as_str());
    if is_waiver(lines[idx]) && block_has_justification(lines, idx) {
        return true;
    }
    // Scan the contiguous comment block directly above.
    let mut j = idx;
    while j > 0 {
        j -= 1;
        let t = lines[j].trim_start();
        if !t.starts_with("//") {
            break;
        }
        if is_waiver(t) {
            return block_has_justification(lines, idx);
        }
    }
    false
}

/// True if the flagged line or the contiguous comment block directly
/// above it contains a `justification:` marker.
fn block_has_justification(lines: &[&str], idx: usize) -> bool {
    if lines[idx].contains("justification:") {
        return true;
    }
    let mut j = idx;
    while j > 0 {
        j -= 1;
        let t = lines[j].trim_start();
        if !t.starts_with("//") && !t.starts_with("#[") {
            break;
        }
        if t.contains("justification:") {
            return true;
        }
    }
    false
}

/// True if the character after byte `end` (exclusive) extends a longer
/// identifier/literal — used to keep `0x5EED_7E57` from matching the
/// `0x5EED` contract seed.
fn extends_literal(line: &str, end: usize) -> bool {
    line.as_bytes()
        .get(end)
        .is_some_and(|b| b.is_ascii_alphanumeric() || *b == b'_')
}

/// Run every applicable rule over one file. `rel_path` is the repo-root
/// relative, `/`-separated path (it selects which rules apply);
/// `content` is the file text.
pub fn check_file(rel_path: &str, content: &str) -> Vec<Finding> {
    let lines: Vec<&str> = content.lines().collect();
    let stripped: Vec<&str> = lines.iter().map(|l| strip_comment(l)).collect();
    let test_start = test_section_start(&lines);
    let in_coordinator = rel_path.starts_with("rust/src/coordinator/");
    // The robustness harness (rust/src/testutil/) shares the no-panic
    // contract: it is production-compiled library code whose failures
    // must be `Result<_, String>` repro reports, never panics — the
    // calling tests/drivers decide how to fail. (doc-failure stays
    // coordinator-only: testutil's API does not speak EvalError.)
    let no_panic_scope = in_coordinator || rel_path.starts_with("rust/src/testutil/");
    let plane_generic = PLANE_GENERIC_MODULES.contains(&rel_path);

    let mut findings = Vec::new();
    let mut push = |rule: &'static str, line: usize, message: String| {
        findings.push(Finding { rule, path: rel_path.to_string(), line: line + 1, message });
    };

    // ---- line-local rules -------------------------------------------
    let mut hot_region_open: Option<usize> = None;
    for idx in 0..lines.len().min(test_start) {
        let raw = lines[idx];
        let code = stripped[idx];

        // hot-alloc region tracking runs on raw lines (the markers are
        // comments). Check the end marker first: "hot-loop-end" contains
        // "hot-loop".
        if raw.contains("xtask: hot-loop-end") {
            if hot_region_open.is_none() {
                push("hot-alloc", idx, "hot-loop-end marker with no open region".to_string());
            }
            hot_region_open = None;
        } else if raw.contains("xtask: hot-loop") {
            if let Some(open) = hot_region_open {
                push(
                    "hot-alloc",
                    idx,
                    format!("nested hot-loop marker (region opened at line {})", open + 1),
                );
            }
            hot_region_open = Some(idx);
        }

        if no_panic_scope {
            for tok in PANIC_TOKENS {
                if code.contains(tok) && !has_waiver(&lines, idx, "no-panic") {
                    push(
                        "no-panic",
                        idx,
                        format!(
                            "`{tok}` in serving-core/testutil non-test code: every failure \
                             here must be a typed answer (EvalError/RejectReason) or a \
                             Result repro report"
                        ),
                    );
                }
            }
        }

        if hot_region_open.is_some() {
            for tok in ALLOC_TOKENS {
                if code.contains(tok) && !has_waiver(&lines, idx, "hot-alloc") {
                    push(
                        "hot-alloc",
                        idx,
                        format!("`{tok}` allocates inside a hot-loop region; reuse scratch buffers"),
                    );
                }
            }
        }

        // seed-literal: contract seeds only via their named pub consts.
        if !code.contains("pub const") {
            let mut from = 0;
            while let Some(pos) = code[from..].find("0x5EED") {
                let at = from + pos;
                let end = at + "0x5EED".len();
                if !extends_literal(code, end) && !has_waiver(&lines, idx, "seed-literal") {
                    push(
                        "seed-literal",
                        idx,
                        "raw 0x5EED: use coordinator::request::DEFAULT_STREAM_SEED".to_string(),
                    );
                }
                from = end;
            }
            let no_underscores: String = code.chars().filter(|c| *c != '_').collect();
            if no_underscores.contains("0x9E3779B97F4A7C15")
                && !has_waiver(&lines, idx, "seed-literal")
            {
                push(
                    "seed-literal",
                    idx,
                    "raw golden-gamma literal: use util::prng::GOLDEN_GAMMA".to_string(),
                );
            }
        }

        if plane_generic && code.contains("::<u64>") && !has_waiver(&lines, idx, "plane-default")
        {
            push(
                "plane-default",
                idx,
                "hardcoded `::<u64>` in a width-generic module: stay generic over \
                 BitPlane or fan out via for_each_plane_width!"
                    .to_string(),
            );
        }

        if (code.contains("#[allow(") || code.contains("#![allow("))
            && !block_has_justification(&lines, idx)
            && !has_waiver(&lines, idx, "allow-attr")
        {
            push(
                "allow-attr",
                idx,
                "#[allow(…)] without a `// justification:` comment (lint policy in \
                 rust/src/lib.rs)"
                    .to_string(),
            );
        }
    }
    if let Some(open) = hot_region_open {
        push("hot-alloc", open, "hot-loop region never closed (missing hot-loop-end)".to_string());
    }

    // ---- doc-failure: pub fn docs in the serving core ---------------
    if in_coordinator {
        for idx in 0..lines.len().min(test_start) {
            if !stripped[idx].trim_start().starts_with("pub fn ") {
                continue;
            }
            if has_waiver(&lines, idx, "doc-failure") {
                continue;
            }
            // Doc block: contiguous `///` / `//` / `#[…]` lines above.
            let mut doc = String::new();
            let mut has_doc = false;
            let mut j = idx;
            while j > 0 {
                j -= 1;
                let t = lines[j].trim_start();
                if t.starts_with("///") {
                    has_doc = true;
                    doc.push_str(t);
                    doc.push('\n');
                } else if t.starts_with("//") || t.starts_with("#[") {
                    continue;
                } else {
                    break;
                }
            }
            if !has_doc {
                push(
                    "doc-failure",
                    idx,
                    "undocumented pub fn in the serving core".to_string(),
                );
                continue;
            }
            // Signature: this line up to the body brace (or `;`).
            let mut sig = String::new();
            for k in idx..lines.len().min(idx + 16) {
                sig.push_str(stripped[k]);
                sig.push(' ');
                if stripped[k].contains('{') || stripped[k].trim_end().ends_with(';') {
                    break;
                }
            }
            // Only the *return type* binds the doc: text after the last
            // `->` (closure params in arguments precede it).
            if let Some(arrow) = sig.rfind("->") {
                let ret = &sig[arrow..];
                for ty in ["EvalError", "RejectReason"] {
                    if ret.contains(ty) && !doc.contains(ty) {
                        push(
                            "doc-failure",
                            idx,
                            format!(
                                "pub fn returns {ty} but its doc never names the failure mode"
                            ),
                        );
                    }
                }
            }
        }
    }

    findings
}

/// Walk `<root>/rust/src` and run [`check_file`] over every `.rs` file.
/// Returns findings sorted by path then line; an empty vector means the
/// repo satisfies every mechanically-enforced invariant in this layer.
pub fn verify_repo(root: &Path) -> std::io::Result<Vec<Finding>> {
    let mut files = Vec::new();
    collect_rs_files(&root.join("rust").join("src"), &mut files)?;
    files.sort();
    let mut findings = Vec::new();
    for path in files {
        let rel = path
            .strip_prefix(root)
            .unwrap_or(&path)
            .components()
            .map(|c| c.as_os_str().to_string_lossy())
            .collect::<Vec<_>>()
            .join("/");
        let content = std::fs::read_to_string(&path)?;
        findings.extend(check_file(&rel, &content));
    }
    findings.sort_by(|a, b| (&a.path, a.line).cmp(&(&b.path, b.line)));
    Ok(findings)
}

fn collect_rs_files(dir: &Path, out: &mut Vec<std::path::PathBuf>) -> std::io::Result<()> {
    for entry in std::fs::read_dir(dir)? {
        let entry = entry?;
        let path = entry.path();
        if path.is_dir() {
            collect_rs_files(&path, out)?;
        } else if path.extension().is_some_and(|e| e == "rs") {
            out.push(path);
        }
    }
    Ok(())
}

//! Negative fixture: an undocumented lint suppression.

// A nearby comment that never says why.
#[allow(dead_code)]
fn quietly_suppressed() {}

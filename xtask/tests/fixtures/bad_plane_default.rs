//! Negative fixture: hardcoded u64 plane width in a generic module.

/// Pins the 64-lane plane instead of staying generic.
pub fn word_count(n: usize) -> usize {
    helper::<u64>(n)
}

fn helper<P>(n: usize) -> usize {
    n
}

//! Negative fixture: fresh heap allocation inside a hot-loop region.

/// Allocates per iteration where the marker bans it.
pub fn hot(xs: &[f64]) -> f64 {
    let mut acc = 0.0;
    // xtask: hot-loop — fixture region
    for &x in xs {
        let v: Vec<f64> = vec![x; 4];
        let doubled: Vec<f64> = v.iter().map(|y| y * 2.0).collect();
        acc += doubled.iter().sum::<f64>();
    }
    // xtask: hot-loop-end
    acc
}

/// Opens a region and never closes it.
pub fn unterminated(xs: &[f64]) -> f64 {
    // xtask: hot-loop — fixture region with a missing end marker
    xs.iter().sum()
}

//! Positive fixture: exercises every rule's *allowed* form. Checked as
//! `rust/src/coordinator/clean.rs`, so the coordinator-only rules apply.

/// Contract seed; a raw literal is legal on its `pub const` definition.
pub const DEFAULT_STREAM_SEED: u64 = 0x5EED;

/// A typed failure for the fixture's API.
pub enum EvalError {
    /// The engine failed.
    Engine(String),
}

/// Double a value, counting in scratch. Returns [`EvalError`] if the
/// input is non-finite (the typed failure mode, named as required).
pub fn eval(x: f64, scratch: &mut Vec<f64>) -> Result<f64, EvalError> {
    if !x.is_finite() {
        return Err(EvalError::Engine("non-finite".into()));
    }
    // xtask: hot-loop — fixture region: reuse-only operations are fine.
    scratch.clear();
    for i in 0..4 {
        scratch.push(x * i as f64);
    }
    let total: f64 = scratch.iter().sum();
    // xtask: hot-loop-end
    Ok(total)
}

/// Seed helper referencing the named constant, never the raw literal.
pub fn stream_seed(i: u64) -> u64 {
    DEFAULT_STREAM_SEED ^ i
}

/// Waived panicking call: the waiver carries its justification.
pub fn must_start(ok: bool) {
    // xtask: allow(no-panic) justification: fixture models a startup-only
    // invariant where dying loudly is the contract.
    assert!(ok);
    if !ok {
        // xtask: allow(no-panic) justification: unreachable by the assert
        // above; fixture exercises the waiver grammar on panic!.
        panic!("cannot happen");
    }
}

// justification: fixture demonstrates a documented allow.
#[allow(dead_code)]
fn helper() {}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn raw_literals_and_panics_are_test_legal() {
        // Test code pins the contract from the outside: raw seeds and
        // unwraps are exempt here.
        assert_eq!(stream_seed(0), 0x5EED);
        assert_eq!(0x9E3779B97F4A7C15u64.count_ones(), 38);
        let v: Result<u64, ()> = Ok(1);
        assert_eq!(v.unwrap(), 1);
    }
}

//! Negative fixture: resilient-client code that must trip the no-panic
//! and doc'd-failure rules — proving the lints cover
//! `coordinator/client.rs` like the rest of the serving core.

/// The client's typed failure for this fixture.
pub enum EvalError {
    /// The breaker refused the call.
    CircuitOpen,
}

/// Documented, but unwraps the hedge winner instead of surfacing a
/// typed error.
pub fn hedged(winner: Option<u32>) -> u32 {
    winner.unwrap()
}

pub fn undocumented_retry(attempt: u32) -> u32 {
    attempt + 1
}

/// Documented, but never names the typed failure mode of its ladder.
pub fn submit_with_retries(x: u32) -> Result<u32, EvalError> {
    Ok(x)
}

//! Negative fixture: robustness-harness code that must trip the
//! no-panic and seed-literal rules — proving the lints cover
//! `rust/src/testutil/` (ISSUE 10), whose non-test code promises
//! `Result<_, String>` repro reports instead of panics and named seed
//! constants instead of raw contract literals.

/// Unwraps a shrink step instead of returning the repro report.
pub fn shrunk(case: Option<u64>) -> u64 {
    case.unwrap()
}

/// Raw contract seed instead of `DEFAULT_STREAM_SEED`.
pub fn stream_seed(i: u64) -> u64 {
    0x5EED ^ i
}

#[cfg(test)]
mod tests {
    /// Raw literals in the trailing test section stay exempt — tests pin
    /// the contract from the outside.
    #[test]
    fn raw_seed_is_fine_here() {
        assert_eq!(super::stream_seed(0), 0x5EED);
    }
}

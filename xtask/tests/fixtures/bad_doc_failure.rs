//! Negative fixture: serving-core pub fns with missing/incomplete docs.

/// A typed failure for the fixture's API.
pub enum EvalError {
    /// The engine failed.
    Engine(String),
}

pub fn undocumented(x: f64) -> f64 {
    x * 2.0
}

/// Documented, but never names the typed failure mode it returns.
pub fn vague(
    x: f64,
) -> Result<f64, EvalError> {
    Ok(x)
}

//! Negative fixture: panicking calls in serving-core non-test code.

/// Looks documented, still panics.
pub fn shaky(v: Option<u32>) -> u32 {
    let x = v.unwrap();
    if x > 10 {
        panic!("too big");
    }
    x
}

/// A waiver without a justification must NOT parse as a waiver.
pub fn half_waived(v: Option<u32>) -> u32 {
    // xtask: allow(no-panic)
    v.expect("missing")
}

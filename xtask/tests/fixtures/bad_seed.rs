//! Negative fixture: raw contract-seed literals outside `pub const`.

/// Uses the raw stream seed instead of DEFAULT_STREAM_SEED.
pub fn stream_seed(i: u64) -> u64 {
    0x5EED ^ i
}

/// Uses the raw (underscored) golden gamma instead of GOLDEN_GAMMA.
pub fn mix(seed: u64) -> u64 {
    seed.wrapping_mul(0x9E37_79B9_7F4A_7C15)
}

/// A *different* literal sharing the prefix is not the contract seed.
pub fn unrelated(seed: u64) -> u64 {
    seed.wrapping_add(0x5EED_7E57)
}

//! The real repository must satisfy every invariant: this is the same
//! pass CI's `static-analysis` job runs (`cargo run -p xtask -- verify`),
//! wired into the test suite so `cargo test` on the workspace enforces
//! the invariants too.

use std::path::Path;

#[test]
fn repository_satisfies_all_invariants() {
    let root = Path::new(env!("CARGO_MANIFEST_DIR"))
        .parent()
        .expect("xtask sits one level below the repo root");
    let findings = xtask::verify_repo(root).expect("walking rust/src must succeed");
    assert!(
        findings.is_empty(),
        "xtask verify found {} violation(s):\n{}",
        findings.len(),
        findings.iter().map(|f| f.to_string()).collect::<Vec<_>>().join("\n")
    );
}

//! Unit tests for the lint engine itself: each rule has a fixture that
//! must fail it (with the exact expected findings) and the `clean.rs`
//! fixture must pass everything — so a regression in the engine cannot
//! silently stop enforcing an invariant.

use xtask::check_file;

fn rule_names(findings: &[xtask::Finding]) -> Vec<&'static str> {
    findings.iter().map(|f| f.rule).collect()
}

#[test]
fn clean_fixture_passes_every_rule() {
    let findings = check_file(
        "rust/src/coordinator/clean.rs",
        include_str!("fixtures/clean.rs"),
    );
    assert!(findings.is_empty(), "clean fixture must pass, got: {findings:?}");
}

#[test]
fn no_panic_flags_unwrap_expect_and_panic() {
    let findings = check_file(
        "rust/src/coordinator/bad.rs",
        include_str!("fixtures/bad_no_panic.rs"),
    );
    assert_eq!(
        rule_names(&findings),
        vec!["no-panic", "no-panic", "no-panic"],
        "{findings:?}"
    );
    // The third hit is the `.expect(` behind a justification-less waiver:
    // a waiver without a reason must not parse.
    assert!(findings[2].message.contains(".expect("), "{findings:?}");
}

#[test]
fn hot_alloc_flags_allocations_and_unterminated_regions() {
    let findings = check_file(
        "rust/src/sc/hot.rs",
        include_str!("fixtures/bad_hot_alloc.rs"),
    );
    assert_eq!(
        rule_names(&findings),
        vec!["hot-alloc", "hot-alloc", "hot-alloc"],
        "{findings:?}"
    );
    assert!(findings[0].message.contains("vec!["), "{findings:?}");
    assert!(findings[1].message.contains(".collect("), "{findings:?}");
    assert!(findings[2].message.contains("never closed"), "{findings:?}");
}

#[test]
fn seed_literal_flags_raw_seeds_but_not_lookalikes() {
    let findings = check_file(
        "rust/src/smurf/sim.rs",
        include_str!("fixtures/bad_seed.rs"),
    );
    assert_eq!(
        rule_names(&findings),
        vec!["seed-literal", "seed-literal"],
        "0x5EED_7E57 must not match the 0x5EED contract seed: {findings:?}"
    );
    assert!(findings[0].message.contains("DEFAULT_STREAM_SEED"));
    assert!(findings[1].message.contains("GOLDEN_GAMMA"));
}

#[test]
fn plane_default_flags_hardcoded_u64_turbofish() {
    let findings = check_file(
        "rust/src/sc/rng.rs",
        include_str!("fixtures/bad_plane_default.rs"),
    );
    assert_eq!(rule_names(&findings), vec!["plane-default"], "{findings:?}");
    // The same content outside the width-generic module list is legal.
    let elsewhere = check_file(
        "rust/src/hw/cost.rs",
        include_str!("fixtures/bad_plane_default.rs"),
    );
    assert!(elsewhere.is_empty(), "{elsewhere:?}");
}

#[test]
fn doc_failure_flags_missing_docs_and_unnamed_failure_modes() {
    let findings = check_file(
        "rust/src/coordinator/bad.rs",
        include_str!("fixtures/bad_doc_failure.rs"),
    );
    assert_eq!(
        rule_names(&findings),
        vec!["doc-failure", "doc-failure"],
        "{findings:?}"
    );
    assert!(findings[0].message.contains("undocumented"), "{findings:?}");
    assert!(findings[1].message.contains("EvalError"), "{findings:?}");
    // The doc rules are coordinator-scoped.
    let elsewhere = check_file("rust/src/hw/cost.rs", include_str!("fixtures/bad_doc_failure.rs"));
    assert!(elsewhere.is_empty(), "{elsewhere:?}");
}

#[test]
fn client_module_is_covered_by_no_panic_and_doc_failure() {
    // The resilient client (ISSUE 9) lives at coordinator/client.rs and
    // must sit under the same serving-core lint umbrella: panicking
    // calls and undocumented/vague failure APIs all fire there.
    let findings = check_file(
        "rust/src/coordinator/client.rs",
        include_str!("fixtures/bad_client.rs"),
    );
    assert_eq!(
        rule_names(&findings),
        vec!["no-panic", "doc-failure", "doc-failure"],
        "{findings:?}"
    );
    assert!(findings[0].message.contains(".unwrap()"), "{findings:?}");
    assert!(findings[1].message.contains("undocumented"), "{findings:?}");
    assert!(findings[2].message.contains("EvalError"), "{findings:?}");
}

#[test]
fn testutil_module_is_covered_by_no_panic_and_seed_hygiene() {
    // The robustness harness (ISSUE 10) is production-compiled library
    // code at rust/src/testutil/: panicking calls and raw contract-seed
    // literals must both fire there, exactly as in the serving core.
    let findings = check_file(
        "rust/src/testutil/soak.rs",
        include_str!("fixtures/bad_testutil.rs"),
    );
    assert_eq!(rule_names(&findings), vec!["no-panic", "seed-literal"], "{findings:?}");
    assert!(findings[0].message.contains(".unwrap()"), "{findings:?}");
    assert!(findings[1].message.contains("0x5EED"), "{findings:?}");
    // The same content outside the covered scopes fires only the
    // repo-wide seed-literal rule — pinning that the no-panic coverage
    // really comes from the testutil path prefix.
    let elsewhere = check_file(
        "rust/src/synth/functions.rs",
        include_str!("fixtures/bad_testutil.rs"),
    );
    assert_eq!(rule_names(&elsewhere), vec!["seed-literal"], "{elsewhere:?}");
}

#[test]
fn allow_attr_requires_justification() {
    let findings = check_file(
        "rust/src/nn/layers.rs",
        include_str!("fixtures/bad_allow_attr.rs"),
    );
    assert_eq!(rule_names(&findings), vec!["allow-attr"], "{findings:?}");
}

// ---- grammar/edge cases on inline snippets --------------------------

#[test]
fn trailing_test_section_is_exempt() {
    let src = "\
/// Doc'd.
pub fn fine() {}

#[cfg(test)]
mod tests {
    #[test]
    fn t() {
        let v: Option<u32> = Some(1);
        assert_eq!(v.unwrap() ^ 0x5EED, 0x5EEC);
    }
}
";
    assert!(check_file("rust/src/coordinator/x.rs", src).is_empty());
}

#[test]
fn indented_cfg_test_does_not_end_the_checked_region() {
    // A test-only helper mid-file (indented #[cfg(test)]) must not
    // exempt the code *after* it.
    let src = "\
/// Doc'd.
pub struct S;

impl S {
    #[cfg(test)]
    fn helper(&self) {}
}

/// Doc'd but panicking.
pub fn still_checked(v: Option<u32>) -> u32 {
    v.unwrap()
}
";
    let findings = check_file("rust/src/coordinator/x.rs", src);
    assert_eq!(rule_names(&findings), vec!["no-panic"], "{findings:?}");
}

#[test]
fn comments_do_not_trip_token_rules() {
    let src = "\
/// This doc mentions panic!(...) and .unwrap() and 0x5EED freely.
// So does this comment: vec![0x9E3779B97F4A7C15].
pub fn quiet() {}
";
    assert!(check_file("rust/src/coordinator/x.rs", src).is_empty());
}

#[test]
fn waiver_on_preceding_comment_block_applies() {
    let src = "\
/// Doc'd.
pub fn startup() {
    // xtask: allow(no-panic) justification: startup-only invariant;
    // dying loudly here is the documented contract.
    Option::<u32>::None.expect(\"boom\");
}
";
    assert!(check_file("rust/src/coordinator/x.rs", src).is_empty());
}

#[test]
fn string_literals_do_not_hide_code_after_them() {
    // A `//` inside a string is not a comment: the `.unwrap()` after the
    // string must still be seen.
    let src = "\
/// Doc'd.
pub fn sneaky(v: Option<&str>) -> &str {
    let _url = \"https://example.com\";
    v.unwrap()
}
";
    let findings = check_file("rust/src/coordinator/x.rs", src);
    assert_eq!(rule_names(&findings), vec!["no-panic"], "{findings:?}");
}
